"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mux_score import mux_score
from repro.kernels.paged_attention import paged_attention
from repro.kernels.selective_scan import selective_scan

KEY = jax.random.key(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,t,h,k,hd,vd,window,chunk,cap",
    [
        (2, 128, 128, 4, 2, 64, 64, None, None, None),     # GQA causal
        (1, 256, 256, 4, 4, 64, 64, 64, None, None),       # sliding window
        (2, 96, 96, 4, 1, 32, 32, None, None, 50.0),       # MQA + softcap
        (1, 256, 256, 8, 2, 64, 64, None, 96, None),       # chunked local
        (2, 64, 192, 4, 2, 64, 32, None, None, None),      # kv-longer + vd!=hd
    ])
def test_flash_attention_sweep(b, s, t, h, k, hd, vd, window, chunk, cap,
                               dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, s, h, hd)).astype(dtype)
    kmat = jax.random.normal(kk, (b, t, k, hd)).astype(dtype)
    v = jax.random.normal(kv, (b, t, k, vd)).astype(dtype)
    out = flash_attention(q, kmat, v, causal=True, window=window, chunk=chunk,
                          logit_cap=cap, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, kmat, v, causal=True, window=window,
                                   chunk=chunk, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize(
    "b,h,k,hd,vd,pages,ps,m,window,chunk,cap",
    [
        (3, 4, 2, 16, 16, 10, 8, 4, None, None, None),   # GQA
        (2, 4, 1, 32, 32, 8, 4, 5, 7, None, None),       # MQA + window
        (1, 8, 2, 16, 8, 12, 8, 3, None, 6, None),       # chunked, vd != hd
        (2, 2, 2, 16, 16, 6, 16, 2, None, None, 25.0),   # softcap
    ])
def test_paged_attention_sweep(b, h, k, hd, vd, pages, ps, m, window, chunk,
                               cap):
    """Pallas paged decode (interpret) vs the gather oracle: per-row
    lengths, block-table indirection, window/chunk masks."""
    kq, kk, kv, kt = jax.random.split(KEY, 4)
    q = jax.random.normal(kq, (b, h, hd))
    k_pages = jax.random.normal(kk, (pages, ps, k, hd))
    v_pages = jax.random.normal(kv, (pages, ps, k, vd))
    # each row gets m distinct pages drawn from 1..pages-1 (0 = scratch)
    perm = np.stack([np.random.RandomState(i).permutation(pages - 1)[:m] + 1
                     for i in range(b)])
    bt = jnp.asarray(perm, jnp.int32)
    lengths = jnp.asarray(
        np.random.RandomState(7).randint(1, m * ps + 1, size=(b,)), jnp.int32)
    out = paged_attention(q, k_pages, v_pages, bt, lengths, window=window,
                          chunk=chunk, logit_cap=cap, interpret=True)
    want = ref.paged_attention_ref(q, k_pages, v_pages, bt, lengths,
                                   window=window, chunk=chunk, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_attention_v_dim_is_k_slice():
    """v_dim reads v as the leading features of the k slab — the
    absorbed-MLA latent layout (v = c_kv slice, one DMA per page)."""
    b, h, hd, ps, m, pages, vdim = 2, 4, 24, 4, 3, 8, 16
    kq, kk = jax.random.split(KEY)
    q = jax.random.normal(kq, (b, h, hd))
    k_pages = jax.random.normal(kk, (pages, ps, 1, hd))     # MQA latent
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    lengths = jnp.asarray([m * ps, 5], jnp.int32)
    out = paged_attention(q, k_pages, k_pages, bt, lengths, v_dim=vdim,
                          interpret=True)
    want = ref.paged_attention_ref(q, k_pages, k_pages[..., :vdim], bt,
                                   lengths)
    assert out.shape == (b, h, vdim)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_attention_int8_dequant_in_kernel():
    """int8 pages + bf16 scale slabs: kernel dequantizes after the page
    DMA and stays within quantisation error of an unquantized pool."""
    from repro.models.attention import (init_paged_kv_cache,
                                        paged_cache_prefill)
    b, h, k, hd, ps, m = 2, 4, 2, 16, 4, 3
    pages = 1 + b * m
    kk = jax.random.normal(jax.random.fold_in(KEY, 1), (b, m * ps, k, hd))
    vv = jax.random.normal(jax.random.fold_in(KEY, 2), (b, m * ps, k, hd))
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (b, h, hd))
    bt = jnp.asarray(np.arange(1, pages).reshape(b, m), jnp.int32)
    lengths = jnp.asarray([m * ps, 2 * ps - 1], jnp.int32)
    outs = {}
    for dt in (jnp.float32, jnp.int8):
        cache = init_paged_kv_cache(pages, ps, k, hd, dtype=dt)
        cache = paged_cache_prefill(cache, kk, vv, bt, start=0)
        outs[dt] = paged_attention(
            q, cache["k"], cache["v"], bt, lengths,
            k_scales=cache.get("k_scale"), v_scales=cache.get("v_scale"),
            interpret=True)
    np.testing.assert_allclose(np.asarray(outs[jnp.int8], np.float32),
                               np.asarray(outs[jnp.float32], np.float32),
                               atol=0.06)


@pytest.mark.parametrize("b,s,d,n,chunk,bd", [
    (2, 128, 64, 16, 64, 32),
    (1, 256, 128, 8, 128, 128),
    (2, 64, 32, 4, 32, 32),
])
def test_selective_scan_sweep(b, s, d, n, chunk, bd):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    am = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    dv = jnp.ones((d,))
    y = selective_scan(x, dt, bm, cm, am, dv, chunk=chunk, block_d=bd,
                       interpret=True)
    want, _ = ref.selective_scan_ref(x, dt, bm, cm, am, dv)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_selective_scan_matches_decode_chain():
    """Chunked kernel == running the per-token recurrence sequentially."""
    b, s, d, n = 1, 32, 16, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    am = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    dv = jnp.zeros((d,))
    y = selective_scan(x, dt, bm, cm, am, dv, chunk=8, block_d=16,
                       interpret=True)
    h = jnp.zeros((b, d, n))
    outs = []
    for t in range(s):
        decay = jnp.exp(dt[:, t, :, None] * am[None])
        h = decay * h + (dt[:, t] * x[:, t])[:, :, None] * bm[:, t, None, :]
        outs.append(jnp.einsum("bdn,bn->bd", h, cm[:, t]))
    want = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("b,m,n", [(10, 64, 6), (300, 32, 2), (7, 128, 16)])
def test_mux_score_sweep(b, m, n):
    meta = jax.random.normal(KEY, (b, m))
    v = jax.random.normal(KEY, (n, m))
    c = jnp.arange(1.0, n + 1)
    w = mux_score(meta, v, c, interpret=True, block_b=64)
    want = ref.mux_score_ref(meta, v, c)
    np.testing.assert_allclose(np.asarray(w), np.asarray(want), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
