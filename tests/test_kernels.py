"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mux_score import mux_score
from repro.kernels.selective_scan import selective_scan

KEY = jax.random.key(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,t,h,k,hd,vd,window,chunk,cap",
    [
        (2, 128, 128, 4, 2, 64, 64, None, None, None),     # GQA causal
        (1, 256, 256, 4, 4, 64, 64, 64, None, None),       # sliding window
        (2, 96, 96, 4, 1, 32, 32, None, None, 50.0),       # MQA + softcap
        (1, 256, 256, 8, 2, 64, 64, None, 96, None),       # chunked local
        (2, 64, 192, 4, 2, 64, 32, None, None, None),      # kv-longer + vd!=hd
    ])
def test_flash_attention_sweep(b, s, t, h, k, hd, vd, window, chunk, cap,
                               dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, s, h, hd)).astype(dtype)
    kmat = jax.random.normal(kk, (b, t, k, hd)).astype(dtype)
    v = jax.random.normal(kv, (b, t, k, vd)).astype(dtype)
    out = flash_attention(q, kmat, v, causal=True, window=window, chunk=chunk,
                          logit_cap=cap, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, kmat, v, causal=True, window=window,
                                   chunk=chunk, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("b,s,d,n,chunk,bd", [
    (2, 128, 64, 16, 64, 32),
    (1, 256, 128, 8, 128, 128),
    (2, 64, 32, 4, 32, 32),
])
def test_selective_scan_sweep(b, s, d, n, chunk, bd):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    am = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    dv = jnp.ones((d,))
    y = selective_scan(x, dt, bm, cm, am, dv, chunk=chunk, block_d=bd,
                       interpret=True)
    want, _ = ref.selective_scan_ref(x, dt, bm, cm, am, dv)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_selective_scan_matches_decode_chain():
    """Chunked kernel == running the per-token recurrence sequentially."""
    b, s, d, n = 1, 32, 16, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    am = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    dv = jnp.zeros((d,))
    y = selective_scan(x, dt, bm, cm, am, dv, chunk=8, block_d=16,
                       interpret=True)
    h = jnp.zeros((b, d, n))
    outs = []
    for t in range(s):
        decay = jnp.exp(dt[:, t, :, None] * am[None])
        h = decay * h + (dt[:, t] * x[:, t])[:, :, None] * bm[:, t, None, :]
        outs.append(jnp.einsum("bdn,bn->bd", h, cm[:, t]))
    want = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("b,m,n", [(10, 64, 6), (300, 32, 2), (7, 128, 16)])
def test_mux_score_sweep(b, m, n):
    meta = jax.random.normal(KEY, (b, m))
    v = jax.random.normal(KEY, (n, m))
    c = jnp.arange(1.0, n + 1)
    w = mux_score(meta, v, c, interpret=True, block_b=64)
    want = ref.mux_score_ref(meta, v, c)
    np.testing.assert_allclose(np.asarray(w), np.asarray(want), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
