"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mux_score import mux_score
from repro.kernels.paged_attention import paged_attention
from repro.kernels.selective_scan import selective_scan

KEY = jax.random.key(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,t,h,k,hd,vd,window,chunk,cap",
    [
        (2, 128, 128, 4, 2, 64, 64, None, None, None),     # GQA causal
        (1, 256, 256, 4, 4, 64, 64, 64, None, None),       # sliding window
        (2, 96, 96, 4, 1, 32, 32, None, None, 50.0),       # MQA + softcap
        (1, 256, 256, 8, 2, 64, 64, None, 96, None),       # chunked local
        (2, 64, 192, 4, 2, 64, 32, None, None, None),      # kv-longer + vd!=hd
    ])
def test_flash_attention_sweep(b, s, t, h, k, hd, vd, window, chunk, cap,
                               dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, s, h, hd)).astype(dtype)
    kmat = jax.random.normal(kk, (b, t, k, hd)).astype(dtype)
    v = jax.random.normal(kv, (b, t, k, vd)).astype(dtype)
    out = flash_attention(q, kmat, v, causal=True, window=window, chunk=chunk,
                          logit_cap=cap, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, kmat, v, causal=True, window=window,
                                   chunk=chunk, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize(
    "b,h,k,hd,vd,pages,ps,m,window,chunk,cap",
    [
        (3, 4, 2, 16, 16, 10, 8, 4, None, None, None),   # GQA
        (2, 4, 1, 32, 32, 8, 4, 5, 7, None, None),       # MQA + window
        (1, 8, 2, 16, 8, 12, 8, 3, None, 6, None),       # chunked, vd != hd
        (2, 2, 2, 16, 16, 6, 16, 2, None, None, 25.0),   # softcap
    ])
def test_paged_attention_sweep(b, h, k, hd, vd, pages, ps, m, window, chunk,
                               cap):
    """Pallas paged decode (interpret) vs the gather oracle: per-row
    lengths, block-table indirection, window/chunk masks."""
    kq, kk, kv, kt = jax.random.split(KEY, 4)
    q = jax.random.normal(kq, (b, h, hd))
    k_pages = jax.random.normal(kk, (pages, ps, k, hd))
    v_pages = jax.random.normal(kv, (pages, ps, k, vd))
    # each row gets m distinct pages drawn from 1..pages-1 (0 = scratch)
    perm = np.stack([np.random.RandomState(i).permutation(pages - 1)[:m] + 1
                     for i in range(b)])
    bt = jnp.asarray(perm, jnp.int32)
    lengths = jnp.asarray(
        np.random.RandomState(7).randint(1, m * ps + 1, size=(b,)), jnp.int32)
    out = paged_attention(q, k_pages, v_pages, bt, lengths, window=window,
                          chunk=chunk, logit_cap=cap, interpret=True)
    want = ref.paged_attention_ref(q, k_pages, v_pages, bt, lengths,
                                   window=window, chunk=chunk, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_attention_v_dim_is_k_slice():
    """v_dim reads v as the leading features of the k slab — the
    absorbed-MLA latent layout (v = c_kv slice, one DMA per page)."""
    b, h, hd, ps, m, pages, vdim = 2, 4, 24, 4, 3, 8, 16
    kq, kk = jax.random.split(KEY)
    q = jax.random.normal(kq, (b, h, hd))
    k_pages = jax.random.normal(kk, (pages, ps, 1, hd))     # MQA latent
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    lengths = jnp.asarray([m * ps, 5], jnp.int32)
    out = paged_attention(q, k_pages, k_pages, bt, lengths, v_dim=vdim,
                          interpret=True)
    want = ref.paged_attention_ref(q, k_pages, k_pages[..., :vdim], bt,
                                   lengths)
    assert out.shape == (b, h, vdim)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_paged_attention_int8_dequant_in_kernel():
    """int8 pages + bf16 scale slabs: kernel dequantizes after the page
    DMA and stays within quantisation error of an unquantized pool."""
    from repro.models.attention import (init_paged_kv_cache,
                                        paged_cache_prefill)
    b, h, k, hd, ps, m = 2, 4, 2, 16, 4, 3
    pages = 1 + b * m
    kk = jax.random.normal(jax.random.fold_in(KEY, 1), (b, m * ps, k, hd))
    vv = jax.random.normal(jax.random.fold_in(KEY, 2), (b, m * ps, k, hd))
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (b, h, hd))
    bt = jnp.asarray(np.arange(1, pages).reshape(b, m), jnp.int32)
    lengths = jnp.asarray([m * ps, 2 * ps - 1], jnp.int32)
    outs = {}
    for dt in (jnp.float32, jnp.int8):
        cache = init_paged_kv_cache(pages, ps, k, hd, dtype=dt)
        cache = paged_cache_prefill(cache, kk, vv, bt, start=0)
        outs[dt] = paged_attention(
            q, cache["k"], cache["v"], bt, lengths,
            k_scales=cache.get("k_scale"), v_scales=cache.get("v_scale"),
            interpret=True)
    np.testing.assert_allclose(np.asarray(outs[jnp.int8], np.float32),
                               np.asarray(outs[jnp.float32], np.float32),
                               atol=0.06)


def _quantize_pages(pages):
    """Per-(slot, head) symmetric int8 + bf16 scales, like the pool's."""
    sc = np.abs(np.asarray(pages)).max(axis=-1) / 127.0 + 1e-8
    qp = np.clip(np.round(np.asarray(pages) / sc[..., None]), -127, 127)
    return jnp.asarray(qp, jnp.int8), jnp.asarray(sc, jnp.bfloat16)


_GROUP_VARIANTS = {
    "full": {},
    "window": {"window": 9},
    "chunked": {"chunk": 16},
    "mla_vdim": {"v_dim": 8},
}


@pytest.mark.parametrize("qtag", ["bf16", "int8"])
@pytest.mark.parametrize("variant", sorted(_GROUP_VARIANTS))
@pytest.mark.parametrize("g", [1, 2, 4, 8])
def test_paged_grouped_token_identical_to_per_head(g, variant, qtag):
    """The GQA re-grid is a pure traffic optimisation: for every group
    size x mask variant x page dtype, the grouped kernel's output is
    TOKEN-IDENTICAL (bitwise) to the per-head baseline grid on a
    mixed-length batch, and its analytic HBM bytes are exactly 1/g."""
    b, h, hd, ps, m = 3, 8, 16, 8, 4
    kk = h // g
    pages = 1 + b * m
    kw = dict(_GROUP_VARIANTS[variant])
    kq, kp, kv = jax.random.split(jax.random.fold_in(KEY, g), 3)
    q = jax.random.normal(kq, (b, h, hd), jnp.bfloat16)
    k_pages = jax.random.normal(kp, (pages, ps, kk, hd), jnp.bfloat16)
    v_pages = (k_pages if variant == "mla_vdim"
               else jax.random.normal(kv, (pages, ps, kk, hd), jnp.bfloat16))
    ks = vs = None
    if qtag == "int8":
        k_pages, ks = _quantize_pages(k_pages)
        v_pages, vs = (k_pages, ks) if variant == "mla_vdim" \
            else _quantize_pages(v_pages)
    bt = jnp.asarray(np.arange(1, pages).reshape(b, m), jnp.int32)
    lengths = jnp.asarray([5, 17, 32], jnp.int32)     # mixed-length batch
    outs = {}
    for grouped in (True, False):
        outs[grouped] = paged_attention(
            q, k_pages, v_pages, bt, lengths, k_scales=ks, v_scales=vs,
            grouped=grouped, interpret=True, **kw)
    assert np.array_equal(np.asarray(outs[True], np.float32),
                          np.asarray(outs[False], np.float32))
    from repro.kernels.paged_attention import decode_hbm_bytes
    by = {gr: decode_hbm_bytes(k_pages, v_pages, bt, lengths, num_q_heads=h,
                               grouped=gr, window=kw.get("window"),
                               chunk=kw.get("chunk"), v_dim=kw.get("v_dim"))
          for gr in (True, False)}
    assert by[True] * g == by[False]


def test_paged_zero_length_rows_are_exact_zeros():
    """A freshly admitted row can reach the kernel with length 0 (no
    visible tokens): every page is skipped, and _finalize must emit
    exact zeros instead of 0/eps garbage — in kernel AND oracle."""
    b, h, kk, hd, ps, m = 3, 4, 2, 16, 4, 3
    pages = 1 + b * m
    kq, kp = jax.random.split(KEY)
    q = jax.random.normal(kq, (b, h, hd))
    k_pages = jax.random.normal(kp, (pages, ps, kk, hd))
    bt = jnp.asarray(np.arange(1, pages).reshape(b, m), jnp.int32)
    lengths = jnp.asarray([0, 7, 0], jnp.int32)
    for grouped in (True, False):
        out = np.asarray(paged_attention(q, k_pages, k_pages, bt, lengths,
                                         grouped=grouped, interpret=True))
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[0], 0.0)
        np.testing.assert_array_equal(out[2], 0.0)
        assert np.abs(out[1]).max() > 0
    want = np.asarray(ref.paged_attention_ref(q, k_pages, k_pages, bt,
                                              lengths))
    assert np.all(np.isfinite(want))
    np.testing.assert_array_equal(want[[0, 2]], 0.0)


def test_paged_combined_prefetch_matches_separate_operands():
    """decode_prefetch packs (bt, lengths) into one (B, M+1) operand;
    the kernel must read identical liveness from either encoding."""
    from repro.kernels.paged_attention import decode_prefetch
    b, h, kk, hd, ps, m = 2, 8, 2, 16, 8, 4
    pages = 1 + b * m
    kq, kp, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, h, hd))
    k_pages = jax.random.normal(kp, (pages, ps, kk, hd))
    v_pages = jax.random.normal(kv, (pages, ps, kk, hd))
    bt = jnp.asarray(np.arange(1, pages).reshape(b, m), jnp.int32)
    lengths = jnp.asarray([13, 32], jnp.int32)
    pf = decode_prefetch(bt, lengths)
    assert pf.shape == (b, m + 1) and pf.dtype == jnp.int32
    for kw in ({}, {"window": 9}, {"chunk": 16}):
        sep = paged_attention(q, k_pages, v_pages, bt, lengths,
                              interpret=True, **kw)
        comb = paged_attention(q, k_pages, v_pages, bt, lengths,
                               prefetch=pf, interpret=True, **kw)
        assert np.array_equal(np.asarray(sep), np.asarray(comb))


def test_decode_hbm_bytes_accounting():
    """The analytic byte counter mirrors the grid: full-length rows pay
    all pages, masks drop dead pages, int8 pays quantized width + scale
    slabs, and grouped/per-head differ by exactly g."""
    from repro.kernels.paged_attention import decode_hbm_bytes
    ps, kk, hd, m = 8, 2, 16, 4
    h = 8
    k_pages = jnp.zeros((9, ps, kk, hd), jnp.float32)
    bt = np.arange(1, 9).reshape(2, m)
    full = decode_hbm_bytes(k_pages, k_pages, bt, [32, 32], num_q_heads=h)
    # 2 rows x 4 live pages x 2 kv heads x (ps*hd*4 k + ps*hd*4 v)
    assert full == 2 * 4 * kk * (ps * hd * 4 * 2)
    short = decode_hbm_bytes(k_pages, k_pages, bt, [32, 1], num_q_heads=h)
    assert short == full // 8 * 5            # row 1 touches 1 of 4 pages
    win = decode_hbm_bytes(k_pages, k_pages, bt, [32, 32], num_q_heads=h,
                           window=4)
    assert win < full                        # only the trailing page lives
    per_head = decode_hbm_bytes(k_pages, k_pages, bt, [32, 32],
                                num_q_heads=h, grouped=False)
    assert per_head == full * (h // kk)
    q8 = jnp.zeros((9, ps, kk, hd), jnp.int8)
    quant = decode_hbm_bytes(q8, q8, bt, [32, 32], num_q_heads=h)
    assert quant == 2 * 4 * kk * (ps * hd * 1 * 2 + 2 * ps * 2)
    vd = decode_hbm_bytes(k_pages, k_pages, bt, [32, 32], num_q_heads=h,
                          v_dim=hd // 2)
    assert vd == 2 * 4 * kk * (ps * hd * 4 + ps * (hd // 2) * 4)


@pytest.mark.parametrize("b,s,d,n,chunk,bd", [
    (2, 128, 64, 16, 64, 32),
    (1, 256, 128, 8, 128, 128),
    (2, 64, 32, 4, 32, 32),
])
def test_selective_scan_sweep(b, s, d, n, chunk, bd):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    am = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    dv = jnp.ones((d,))
    y = selective_scan(x, dt, bm, cm, am, dv, chunk=chunk, block_d=bd,
                       interpret=True)
    want, _ = ref.selective_scan_ref(x, dt, bm, cm, am, dv)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_selective_scan_matches_decode_chain():
    """Chunked kernel == running the per-token recurrence sequentially."""
    b, s, d, n = 1, 32, 16, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    am = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    dv = jnp.zeros((d,))
    y = selective_scan(x, dt, bm, cm, am, dv, chunk=8, block_d=16,
                       interpret=True)
    h = jnp.zeros((b, d, n))
    outs = []
    for t in range(s):
        decay = jnp.exp(dt[:, t, :, None] * am[None])
        h = decay * h + (dt[:, t] * x[:, t])[:, :, None] * bm[:, t, None, :]
        outs.append(jnp.einsum("bdn,bn->bd", h, cm[:, t]))
    want = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("b,m,n", [(10, 64, 6), (300, 32, 2), (7, 128, 16)])
def test_mux_score_sweep(b, m, n):
    meta = jax.random.normal(KEY, (b, m))
    v = jax.random.normal(KEY, (n, m))
    c = jnp.arange(1.0, n + 1)
    w = mux_score(meta, v, c, interpret=True, block_b=64)
    want = ref.mux_score_ref(meta, v, c)
    np.testing.assert_allclose(np.asarray(w), np.asarray(want), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
