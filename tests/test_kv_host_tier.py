"""KV memory hierarchy correctness contract: spilling a prefix to the
host tier and restoring it on a later hit is invisible to outputs —
token-identical generations with tiering on vs off across
full/window/chunked/GQA/MLA paged variants — and invisible to the
pool's ownership accounting: a cancelled restore leaks nothing, a
watermark keeps admission headroom free, a demand spill completes an
allocation that would otherwise reject, and the disaggregated
backend's staging pool retains transferred prefixes so repeat system
prompts skip the prefill compute."""
import asyncio

import jax
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.serving.backend import DisaggregatedBackend
from repro.serving.engine import Engine, ServeConfig
from repro.serving.kv_cache import OutOfPages
from repro.serving.scheduler import (PagedLLMConfig, PagedLLMScheduler,
                                     SamplingParams)
from test_prefix_sharing import (PS, prompts_with_shared_prefix,
                                 tiny_config)

#: variants where every layer attends the full context, so span
#: reclaim never frees prefix pages mid-decode and the retained /
#: spilled / restored page counts are exact.  Window and chunked
#: attention reclaim pages below their span — chunk 0 then never
#: reaches the host tier and a later lookup is a clean miss (the
#: tolerant branch: parity must still hold, counters need not).
FULL_CONTEXT = ("full", "gqa_mixed", "mla")


def make_tiered_engine(cfg, params, *, num_pages=40, host_pages=16,
                       watermark=0.0, lazy=False) -> Engine:
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=num_pages, page_size=PS, decode_batch=4,
                   prefix_sharing=True, host_tier_pages=host_pages,
                   spill_watermark=watermark, lazy_decode_alloc=lazy)
    return eng


def make_flat_engine(cfg, params, *, num_pages=40) -> Engine:
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=num_pages, page_size=PS, decode_batch=4,
                   prefix_sharing=True)
    return eng


# ---------------------------------------------------------------------------
# Parity: tiering on vs off, all paged variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant",
                         ["full", "swa", "chunked", "gqa_mixed", "mla"])
def test_spill_restore_parity_on_vs_off(variant):
    """Generate, spill everything to host, regenerate: the restored
    prefix (including the partially-filled boundary page) produces
    exactly the tokens a tier-less engine produces, for every paged
    attention variant; a shared-prefix follower restores only the full
    prefix chunks it can use."""
    cfg = tiny_config(variant)
    params = tf.init_params(cfg, jax.random.key(3))
    pa, pb = prompts_with_shared_prefix(cfg)    # 8-token prefix, tails 3/5
    exact = variant in FULL_CONTEXT
    flat = make_flat_engine(cfg, params)
    ref_a = flat.generate_paged(pa, max_new_tokens=6)["tokens"]
    ref_b = flat.generate_paged(pb, max_new_tokens=6)["tokens"]

    eng = make_tiered_engine(cfg, params)
    out_a = eng.generate_paged(pa, max_new_tokens=6)["tokens"]
    np.testing.assert_array_equal(out_a, ref_a)
    retained = eng.pool.retained_pages
    if exact:
        assert retained == 3                    # 2 full chunks + boundary

    eng.pool.drop_retained()                    # force everything cold
    assert eng.pool.pages_in_use == 0
    # single-owner pages spill (never drop): host holds all of them
    assert eng.host_tier.stats()["pages_in_use"] == retained

    # repeat prompt: restore from host, prefill only the final token
    out_a2 = eng.generate_paged(pa, max_new_tokens=6)["tokens"]
    np.testing.assert_array_equal(out_a2, ref_a)
    if exact:
        ht = eng.host_tier.stats()
        assert ht["restored_pages"] == 3 and ht["hits"] == 1
        assert ht["pages_in_use"] == 0          # consumed: one tier owns it

    # partial host hit: pb shares only the 2 full prefix chunks
    eng.pool.drop_retained()
    out_b = eng.generate_paged(pb, max_new_tokens=6)["tokens"]
    np.testing.assert_array_equal(out_b, ref_b)
    if exact:
        assert eng.host_tier.stats()["restored_pages"] == 5

    eng.pool.drop_retained()
    assert eng.pool.pages_in_use == 0 and eng.pool.prefix_entries == 0


def test_restored_prefix_tokens_count_as_shared():
    """A restored prefix is shared compute, not recomputed compute:
    the repeat generation's sealing accounts its restored span in
    prefill_tokens_shared exactly like a resident hit would."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    pa, _ = prompts_with_shared_prefix(cfg)     # len 11: shared cap 10
    eng = make_tiered_engine(cfg, params)
    eng.generate_paged(pa, max_new_tokens=4)
    eng.pool.drop_retained()
    before = eng.prefill_tokens_shared
    eng.generate_paged(pa, max_new_tokens=4)
    assert eng.prefill_tokens_shared - before == len(pa) - 1


# ---------------------------------------------------------------------------
# Pressure behaviour: watermark, spill-not-reject, cancellation
# ---------------------------------------------------------------------------

def test_watermark_spills_proactively_at_release():
    """With a spill watermark, releasing a sequence spills retained
    pages down to the free-page target instead of waiting for an
    allocation to come up short."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    # 12 allocatable pages, target int(0.9 * 12) = 10 free.  The
    # 11-token prompt (+6 budget) retains 3 pages at release, leaving
    # 9 free — one short, so exactly one page spills eagerly.
    eng = make_tiered_engine(cfg, params, num_pages=13, watermark=0.9)
    pa, _ = prompts_with_shared_prefix(cfg)
    eng.generate_paged(pa, max_new_tokens=6)
    st = eng.pool.stats()
    assert st["num_free"] >= 10                 # watermark target held
    assert st["pages_spilled"] == 1 and st["retained_pages"] == 2
    assert eng.host_tier.stats()["pages_in_use"] == 1
    eng.pool.drop_retained()
    assert eng.pool.pages_in_use == 0


def test_demand_spill_completes_would_reject_alloc():
    """The eviction + re-admission trace: a prompt whose allocation
    exceeds free pages (because retention holds the rest) completes by
    spilling the cold prefix — where a flat pool with the same free
    pages raises OutOfPages — and the spilled prefix restores on its
    next admission."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    pa, _ = prompts_with_shared_prefix(cfg)
    pc = np.asarray(jax.random.randint(jax.random.key(99), (16,), 0,
                                       cfg.vocab_size))
    # 8 allocatable pages; A seals holding 5 (11 tokens + 6 budget),
    # retains 3 at release; C needs 4+2 = 6 pages > 5 free
    eng = make_tiered_engine(cfg, params, num_pages=9, host_pages=8)
    eng.generate_paged(pa, max_new_tokens=6)
    assert eng.pool.retained_pages == 3 and eng.pool.num_free == 5
    # a flat pool with 5 free pages rejects this admission outright
    with pytest.raises(OutOfPages):
        make_flat_engine(cfg, params, num_pages=6).prefill_into_pages(
            pc, max_new_tokens=6)
    seq = eng.prefill_into_pages(pc, max_new_tokens=6)   # spills, admits
    assert eng.pool.stats()["pages_spilled"] >= 1
    eng.pool.release(seq)
    # and the spilled prefix is not lost: A's next admission restores
    eng.pool.drop_retained()
    seq_a = eng.prefill_into_pages(pa, max_new_tokens=6)
    assert seq_a.shared_prefix_len == len(pa) - 1
    assert eng.host_tier.stats()["restored_pages"] >= 3
    eng.pool.release(seq_a)
    eng.pool.drop_retained()
    assert eng.pool.pages_in_use == 0


def test_mid_restore_failure_leaks_nothing(monkeypatch):
    """A restore whose scatter dies mid-flight (device failure /
    cancellation) hands its freshly-allocated pages back and leaves
    the host copies untouched — the admission then rolls back to an
    empty pool, pages exact."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    pa, _ = prompts_with_shared_prefix(cfg)
    eng = make_tiered_engine(cfg, params)
    eng.generate_paged(pa, max_new_tokens=6)
    eng.pool.drop_retained()
    assert eng.host_tier.stats()["pages_in_use"] == 3

    def boom(*_a, **_k):
        raise RuntimeError("scatter died mid-restore")
    monkeypatch.setattr(eng, "_tier_scatter", boom)
    with pytest.raises(RuntimeError, match="mid-restore"):
        eng.prefill_into_pages(pa, max_new_tokens=6)
    assert eng.pool.pages_in_use == 0           # new pages handed back
    assert eng.host_tier.stats()["pages_in_use"] == 3   # host intact
    assert eng.host_tier.stats()["restored_pages"] == 0


def test_host_tier_capacity_lru_eviction():
    """A host tier smaller than the spill demand evicts its coldest
    entries; the device side still frees its pages (eviction never
    blocks reclaim)."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    pa, _ = prompts_with_shared_prefix(cfg)
    eng = make_tiered_engine(cfg, params, host_pages=2)
    eng.generate_paged(pa, max_new_tokens=6)
    eng.pool.drop_retained()                    # 3 spill into 2 slots
    ht = eng.host_tier.stats()
    assert ht["pages_in_use"] == 2 and ht["evicted_pages"] >= 1
    assert eng.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# Lazy decode allocation (scheduler admission satellite)
# ---------------------------------------------------------------------------

def test_lazy_decode_alloc_reserves_prompt_only():
    """Lazy sealing holds pages_for(p + 1), not the full
    prompt+budget span; decode then grows page-by-page, and admission
    cost reports the smaller up-front reservation."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    pa, _ = prompts_with_shared_prefix(cfg)     # p = 11
    flat = make_flat_engine(cfg, params)
    assert flat.admission_page_cost(pa, 8)[0] == flat.pool.pages_for(19)
    ref = flat.generate_paged(pa, max_new_tokens=8)["tokens"]

    eng = make_tiered_engine(cfg, params, lazy=True)
    assert eng.admission_page_cost(pa, 8)[0] == eng.pool.pages_for(12)
    seq = eng.prefill_into_pages(pa, max_new_tokens=8)
    assert len(seq.pages) == eng.pool.pages_for(12)     # p + 1 only
    while not seq.done:
        eng.decode_step_batch([seq])
    assert len(seq.pages) == eng.pool.pages_for(len(pa) + 8)
    np.testing.assert_array_equal(
        np.concatenate([pa, np.asarray(seq.tokens, np.int32)]), ref)
    eng.pool.release(seq)
    eng.pool.drop_retained()
    assert eng.pool.pages_in_use == 0


def test_lazy_grow_out_of_pages_tags_victim():
    """A decode step that cannot grow a lazily-sealed sequence raises
    OutOfPages tagged with grow_seq and mutates nothing — the
    scheduler fails only that sequence, exactly like the COW path."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    # 4 allocatable pages: an 11-token prompt seals lazily into 3
    # pages (12-token span); decode crosses into a 4th page at
    # position 12 and needs a 5th at position 16 — which never exists
    eng.init_paged(num_pages=5, page_size=PS, decode_batch=4,
                   prefix_sharing=True, lazy_decode_alloc=True)
    pa = np.asarray(jax.random.randint(jax.random.key(5), (11,), 0,
                                       cfg.vocab_size))
    seq = eng.prefill_into_pages(pa, max_new_tokens=8)
    with pytest.raises(OutOfPages) as ei:
        while not seq.done:
            eng.decode_step_batch([seq])
    assert ei.value.grow_seq is seq
    assert len(seq.pages) == 4                  # grew to the wall first
    eng.pool.release(seq)                       # complete rollback
    assert eng.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# Disaggregated staging retention
# ---------------------------------------------------------------------------

def test_disagg_staging_retains_transferred_prefix():
    """The gather stage's release RETAINS a transferred prefix in the
    tiered staging pool: a repeated system prompt maps it and skips
    the prefill compute (the transfer still copies), token-identical
    to the flat-engine reference."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(3))
    pa, _ = prompts_with_shared_prefix(cfg)
    ref = make_flat_engine(cfg, params).generate_paged(
        pa, max_new_tokens=6)["tokens"]

    backend = DisaggregatedBackend.build(
        cfg, params, ServeConfig(max_len=64), num_pages=40, page_size=PS,
        decode_batch=4, host_tier_pages=16)

    async def run_twice():
        sched = PagedLLMScheduler(backends=[backend], cfg=PagedLLMConfig())
        async with sched:
            out1 = await sched.submit(
                pa, SamplingParams(max_new_tokens=6)).result()
            computed_mid = backend.prefill_engine.prefill_tokens_computed
            out2 = await sched.submit(
                pa, SamplingParams(max_new_tokens=6)).result()
        return out1, out2, computed_mid

    out1, out2, computed_mid = asyncio.run(run_twice())
    np.testing.assert_array_equal(out1, ref)
    np.testing.assert_array_equal(out2, ref)
    pre = backend.prefill_engine
    assert pre.pool.retained_pages >= 3         # staging kept the prefix
    # the repeat ran tail-only: its shared span never recomputed
    assert pre.prefill_tokens_shared >= len(pa) - 1
    assert pre.prefill_tokens_computed - computed_mid <= PS
    assert backend.transfers >= 2               # transfer still copies
    pre.pool.drop_retained()
    assert pre.pool.pages_in_use == 0
