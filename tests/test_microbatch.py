"""Gradient accumulation: m microbatches == one big batch (same grads)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch import steps as steps_mod
from repro.models import transformer as tf
from repro.optim import adamw


def test_microbatched_step_matches_full_batch():
    cfg = get_smoke_config("olmo-1b").with_(compute_dtype="float32")
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    key = jax.random.key(0)
    params = tf.init_params(cfg, key)
    opt_state = adamw.init(opt_cfg, params)
    tok = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}

    p1, _, m1 = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))(
        params, opt_state, batch)
    cfg_mb = cfg.with_(microbatches=4)
    p2, _, m2 = jax.jit(steps_mod.make_train_step(cfg_mb, opt_cfg))(
        params, opt_state, batch)

    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # grads agree to ~1e-8; Adam's first step ~ g/sqrt(g^2) amplifies
    # tiny accumulation-order diffs, so compare params at 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
