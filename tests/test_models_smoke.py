"""Deliverable (f): per-architecture reduced-config smoke tests.

Each assigned architecture instantiates its reduced variant (<=2 pattern
tiles, d_model<=512, <=4 experts) and runs one forward + one train step
on CPU, asserting output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_architectures
from repro.launch import steps as steps_mod
from repro.models import transformer as tf
from repro.optim import adamw

ARCHS = list_architectures()


def _batch(cfg, key, b=2, s=32):
    if cfg.num_codebooks:
        tokens = jax.random.randint(key, (b, s, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model)).astype(cfg.cdtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke_config(arch)
    params = tf.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    h, _, aux = tf.forward(params, cfg, batch["tokens"],
                           image_embeds=batch.get("image_embeds"),
                           mode="train")
    b, s = batch["tokens"].shape[:2]
    assert h.shape == (b, s, cfg.d_model)
    logits = tf.unembed(params, cfg, h)
    if cfg.num_codebooks:
        assert logits.shape == (b, s, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert jnp.isfinite(aux).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch, rng):
    cfg = get_smoke_config(arch)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
    params = tf.init_params(cfg, rng)
    opt_state = adamw.init(opt_cfg, params)
    batch = _batch(cfg, rng)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0.0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_total_params_formula_matches(arch, rng):
    """Analytic total_params (used in roofline) == actual leaf count."""
    from repro.launch.hlo_analysis import total_params
    cfg = get_smoke_config(arch)
    params = tf.init_params(cfg, rng)
    skip = ("norm", "q_norm", "k_norm", "kv_norm", "gate_attn", "gate_mlp",
            "dt_bias", "conv_b", "A_log", "/D")
    actual = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        actual += leaf.size
    est = total_params(cfg)
    # analytic formula ignores norms/biases/ssm-extras (<2% of total)
    assert abs(est - actual) / actual < 0.06, (est, actual)
