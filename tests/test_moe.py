"""MoE dispatch/combine correctness vs a dense (no-capacity) reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _activation
from repro.models.moe import init_moe, moe_ffn, route

KEY = jax.random.key(3)


def dense_reference(params, x, *, num_experts, top_k, router_act, gated):
    """Every token runs through its top-k experts, no capacity limit."""
    b, s, d = x.shape
    w, idx, _ = route(params, x, num_experts=num_experts, top_k=top_k,
                      router_act=router_act)
    out = jnp.zeros_like(x)
    for e in range(num_experts):
        up = x @ params["up"][e]
        h = _activation(x @ params["gate"][e], "silu") * up if gated \
            else _activation(up, "silu")
        y = h @ params["down"][e]
        weight = jnp.where(idx == e, w, 0.0).sum(-1)          # (B,S)
        out = out + y * weight[..., None]
    return out


@pytest.mark.parametrize("router_act,top_k", [
    ("softmax_topk", 2), ("topk_softmax", 2), ("sigmoid", 1)])
def test_moe_matches_dense_reference(router_act, top_k):
    b, s, d, e, f = 2, 16, 32, 4, 64
    params = init_moe(KEY, d_model=d, num_experts=e, moe_d_ff=f, gated=True)
    x = jax.random.normal(KEY, (b, s, d))
    out, aux = moe_ffn(params, x, num_experts=e, top_k=top_k,
                       router_act=router_act, capacity_factor=8.0)
    want = dense_reference(params, x, num_experts=e, top_k=top_k,
                           router_act=router_act, gated=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert jnp.isfinite(aux)


def test_moe_capacity_drops_tokens():
    """With tiny capacity some assignments drop -> output differs from
    the dense reference but stays finite (dropped tokens contribute 0)."""
    b, s, d, e = 1, 32, 16, 2
    params = init_moe(KEY, d_model=d, num_experts=e, moe_d_ff=32)
    x = jax.random.normal(KEY, (b, s, d))
    out_small, _ = moe_ffn(params, x, num_experts=e, top_k=1,
                           router_act="softmax_topk", capacity_factor=0.1)
    out_big, _ = moe_ffn(params, x, num_experts=e, top_k=1,
                         router_act="softmax_topk", capacity_factor=8.0)
    assert jnp.isfinite(out_small).all()
    assert float(jnp.abs(out_small - out_big).max()) > 0.0
    # dropped tokens produce strictly smaller outputs on average
    assert float(jnp.abs(out_small).sum()) < float(jnp.abs(out_big).sum())


def test_moe_dropless_decode_never_drops():
    b, s, d, e = 4, 1, 16, 4
    params = init_moe(KEY, d_model=d, num_experts=e, moe_d_ff=32)
    x = jax.random.normal(KEY, (b, s, d))
    out, _ = moe_ffn(params, x, num_experts=e, top_k=2,
                     router_act="softmax_topk", dropless=True)
    want = dense_reference(params, x, num_experts=e, top_k=2,
                           router_act="softmax_topk", gated=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_shared_expert_added():
    b, s, d, e = 1, 8, 16, 2
    p1 = init_moe(KEY, d_model=d, num_experts=e, moe_d_ff=32, shared_d_ff=32)
    p2 = {k: v for k, v in p1.items() if k != "shared"}
    x = jax.random.normal(KEY, (b, s, d))
    o1, _ = moe_ffn(p1, x, num_experts=e, top_k=1, capacity_factor=8.0)
    o2, _ = moe_ffn(p2, x, num_experts=e, top_k=1, capacity_factor=8.0)
    assert float(jnp.abs(o1 - o2).max()) > 0.0


def test_aux_loss_balanced_vs_collapsed():
    """Aux loss is ~1 for a uniform router and larger when collapsed."""
    b, s, d, e = 8, 64, 16, 8
    params = init_moe(KEY, d_model=d, num_experts=e, moe_d_ff=8)
    x = jax.random.normal(KEY, (b, s, d))
    params_uniform = dict(params, router=jnp.zeros_like(params["router"]))
    _, _, aux_u = route(params_uniform, x, num_experts=e, top_k=1,
                        router_act="softmax_topk")
    collapsed = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    _, _, aux_c = route(dict(params, router=collapsed), x, num_experts=e,
                        top_k=1, router_act="softmax_topk")
    assert float(aux_c) > float(aux_u) * 2
