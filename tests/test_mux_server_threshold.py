"""MuxServer threshold routing: selection policy and capacity sizing.

Thresholded hybrid selection concentrates traffic on the cheapest
clearing model by design, so serve() must size buckets to hold the
whole batch — a balanced cf*B/N capacity would silently zero-fill the
overflow."""
import jax.numpy as jnp
import numpy as np

from repro.serving.mux_server import MuxServer, MuxServerConfig


def _server(threshold):
    # model fns are simple row-wise maps so expected outputs are exact
    fns = [lambda b: b * 2.0, lambda b: b * 3.0]
    server = MuxServer(mux_params={}, model_fns=fns, model_costs=[1.0, 4.0],
                       cfg=MuxServerConfig(threshold=threshold))
    # deterministic probe: every request is 90% confident in the cheap
    # model (patched before the first call, i.e. before jit tracing)
    server._weights = lambda x: jnp.stack(
        [jnp.full((x.shape[0],), 0.9), jnp.full((x.shape[0],), 0.1)], -1)
    return server


def test_threshold_concentration_keeps_every_request():
    server = _server(threshold=0.5)
    x = jnp.arange(24.0).reshape(8, 3)
    res = server.serve(x)
    assign = np.asarray(res["assign"])
    np.testing.assert_array_equal(assign, np.zeros(8))   # all clear -> cheap
    # balanced capacity (1.5*8/2 = 6) would drop 2; threshold mode keeps 8
    assert np.asarray(res["kept"]).all()
    np.testing.assert_allclose(np.asarray(res["output"]),
                               np.asarray(x) * 2.0)
    assert res["called_fraction"] == [1.0, 0.0]


def test_threshold_fallback_to_largest_keeps_every_request():
    server = _server(threshold=0.95)                     # nobody clears
    x = jnp.arange(12.0).reshape(4, 3)
    res = server.serve(x)
    np.testing.assert_array_equal(np.asarray(res["assign"]), np.full(4, 1))
    assert np.asarray(res["kept"]).all()
    np.testing.assert_allclose(np.asarray(res["output"]),
                               np.asarray(x) * 3.0)
