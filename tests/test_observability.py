"""Request-level tracing, gauges, flight recorder, latency attribution
(repro.serving.observability + the SchedulerMetrics tracing bridge).

The tentpole contracts: the ring buffer is bounded and ordered, the
Chrome trace-event export is schema-valid and loads the way Perfetto
expects, a traced serving run emits a *closed* ADMIT -> QUEUED ->
PREFILL -> DECODE -> FINISH chain per completed request (KV_TRANSFER
spans appear exactly on the disaggregated backend), and tracing is
invisible to the tokens — traced and untraced runs produce identical
outputs.  Plus the metrics satellites: rejected-queue accounting,
phase attribution, per-model TTFT/ITL, distinct reservoir seeds, and
mid-run / restart elapsed semantics."""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import transformer as tf
from repro.serving.backend import (DisaggregatedBackend, InProcessBackend,
                                   ModelBackend)
from repro.serving.engine import Engine, ServeConfig
from repro.serving.observability import (NULL_TRACER, Tracer,
                                         backend_track, request_track,
                                         sample_gauges,
                                         validate_chrome_trace)
from repro.serving.scheduler import (PagedLLMConfig, PagedLLMScheduler,
                                     Request, SamplingParams,
                                     SchedulerMetrics)

PS = 4          # page size everywhere here


def tiny_config() -> ModelConfig:
    return ModelConfig(name="obs-tiny", arch_type="dense", num_layers=2,
                       d_model=32, d_ff=64, vocab_size=64, num_heads=4,
                       num_kv_heads=2, head_dim=8, compute_dtype="float32",
                       param_dtype="float32", kv_cache_dtype="float32")


@pytest.fixture(scope="module")
def model():
    cfg = tiny_config()
    return cfg, tf.init_params(cfg, jax.random.key(0))


def make_backend(model, kind) -> ModelBackend:
    cfg, params = model
    if kind == "inproc":
        eng = Engine(cfg, params, ServeConfig(max_len=64))
        eng.init_paged(num_pages=40, page_size=PS, decode_batch=4)
        return InProcessBackend(eng)
    return DisaggregatedBackend.build(
        cfg, params, ServeConfig(max_len=64), num_pages=40, page_size=PS,
        decode_batch=4, prefill_pages=32)


def prompt_of(n, fold=0):
    return np.asarray(jax.random.randint(jax.random.fold_in(
        jax.random.key(5), fold), (n,), 0, tiny_config().vocab_size))


def fake_clock(t=0.0):
    state = {"t": t}

    def clock():
        state["t"] += 0.001
        return state["t"]
    return clock


# ===========================================================================
# Ring buffer + export schema
# ===========================================================================

def test_ring_is_bounded_ordered_and_counts_drops():
    tr = Tracer(capacity=4, clock=fake_clock())
    for i in range(10):
        tr.instant(f"ev{i}")
    evs = tr.events()
    assert len(evs) == 4
    assert [ev[0] for ev in evs] == sorted(ev[0] for ev in evs)
    assert [ev[2] for ev in evs] == ["ev6", "ev7", "ev8", "ev9"]
    st = tr.stats()
    assert st["recorded"] == 10 and st["dropped"] == 6
    assert st["capacity"] == 4


def test_events_since_filters_by_timestamp():
    tr = Tracer(capacity=16, clock=fake_clock())
    tr.instant("old", t=1.0)
    tr.instant("new", t=5.0)
    assert [ev[2] for ev in tr.events(since=2.0)] == ["new"]


def test_null_tracer_is_disabled_noop():
    assert NULL_TRACER.enabled is False
    # every call is a no-op — no ring, no exceptions, nothing recorded
    NULL_TRACER.span("s", "a/b", 0.0, 1.0)
    NULL_TRACER.instant("i")
    NULL_TRACER.counter("c", {"x": 1})
    NULL_TRACER.trip("anything")
    NULL_TRACER.add_consumer(lambda ev: None)


def test_chrome_export_is_schema_valid(tmp_path):
    tr = Tracer(clock=fake_clock())
    tr.span("PREFILL", request_track(3), 1.0, 1.5, {"model": 0})
    tr.span("decode_step", backend_track("m0", "decode"), 1.5, 1.6)
    tr.instant("degrade", args={"rid": 3})
    tr.counter("m0:pool", {"pages_in_use": 7, "num_free": 9})
    path = tmp_path / "trace.json"
    payload = tr.export(str(path))
    assert validate_chrome_trace(payload) == []
    # the file round-trips to the same valid object
    assert validate_chrome_trace(json.loads(path.read_text())) == []
    # track mapping: one pid per group with metadata, µs timestamps
    names = {ev["name"] for ev in payload["traceEvents"]}
    assert {"process_name", "thread_name", "PREFILL", "degrade"} <= names
    span = next(ev for ev in payload["traceEvents"]
                if ev["name"] == "PREFILL")
    assert span["ts"] == pytest.approx(1.0e6) and \
        span["dur"] == pytest.approx(0.5e6)


def test_validator_rejects_malformed_payloads():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({}) != []
    bad_span = {"traceEvents": [{"ph": "X", "name": "s", "pid": 1,
                                 "tid": 1, "ts": 0.0}]}   # missing dur
    assert any("dur" in p for p in validate_chrome_trace(bad_span))
    bad_phase = {"traceEvents": [{"ph": "Q", "name": "s", "pid": 1,
                                  "tid": 1, "ts": 0.0}]}
    assert any("phase" in p for p in validate_chrome_trace(bad_phase))


# ===========================================================================
# Traced serving runs: closed span chains, transfer spans, token parity
# ===========================================================================

PROMPT_LENS = (12, 20, 9, 6)
MAX_NEW = 6


def serve(model, kind, tracer=None):
    backend = make_backend(model, kind)
    sched = PagedLLMScheduler(
        backends=[backend],
        cfg=PagedLLMConfig(max_new_tokens=MAX_NEW, prefill_chunk_pages=2),
        tracer=tracer)
    sched.warmup(sorted(set(PROMPT_LENS)))
    prompts = [prompt_of(n, i) for i, n in enumerate(PROMPT_LENS)]

    async def go():
        async with sched:
            handles = [sched.submit(p) for p in prompts]
            outs = await asyncio.gather(*handles)
            return handles, outs

    handles, outs = asyncio.run(go())
    return sched, handles, [np.asarray(o) for o in outs]


def chain_of(events, rid):
    track = request_track(rid)
    return [(ph, name, ts, dur)
            for _, ph, name, track_, ts, dur, _ in events if track_ == track]


@pytest.mark.parametrize("kind", ["inproc", "disagg"])
def test_traced_run_chains_close_and_tokens_match_untraced(model, kind,
                                                           tmp_path):
    _, _, baseline = serve(model, kind)           # untraced reference
    tracer = Tracer()
    sched, handles, outs = serve(model, kind, tracer=tracer)

    # tracing must be invisible to the tokens
    for ref, out in zip(baseline, outs):
        np.testing.assert_array_equal(ref, out)

    payload = tracer.export(str(tmp_path / f"{kind}.json"))
    assert validate_chrome_trace(payload) == []

    events = tracer.events()
    names = {ev[2] for ev in events}
    assert "DECODE_STEP" in names          # backend decode track spans
    assert any(n.startswith("PREFILL_CHUNK[") for n in names)
    # KV_TRANSFER spans appear exactly on the disaggregated backend
    assert ("KV_TRANSFER" in names) == (kind == "disagg")

    # closed ADMIT -> QUEUED -> PREFILL -> DECODE -> FINISH chain per
    # completed request, with exactly-chained endpoints
    for h in handles:
        req = h.request
        chain = {name: (ph, ts, dur)
                 for ph, name, ts, dur in chain_of(events, req.rid)}
        for name in ("ADMIT", "QUEUED", "PREFILL", "DECODE", "FINISH"):
            assert name in chain, (req.rid, sorted(chain))
        assert chain["ADMIT"][1] == req.admitted_t
        assert chain["QUEUED"][1] == req.admitted_t
        assert chain["QUEUED"][1] + chain["QUEUED"][2] == pytest.approx(
            req.started_t, abs=1e-6)
        assert chain["PREFILL"][1] == req.started_t
        assert chain["DECODE"][1] == req.first_token_t
        assert chain["DECODE"][1] + chain["DECODE"][2] == pytest.approx(
            req.finished_t, abs=1e-6)
        assert chain["FINISH"][1] == req.finished_t
        chunks = [n for _, n, _, _ in chain_of(events, req.rid)
                  if n.startswith("PREFILL_CHUNK[")]
        assert chunks == [f"PREFILL_CHUNK[{i}]"
                          for i in range(len(chunks))] and chunks

    # the flattened dashboard keys ride on the paged snapshot
    snap = sched.snapshot()
    assert snap["pool_pages_in_use"] == 0
    assert snap["prewarm_residents"] >= 0
    assert snap["inflight_chunks"] == 0
    assert 0.0 <= snap["logit_cache_hit_rate"] <= 1.0
    assert snap["trace"]["recorded"] > 0
    # the gauge loop (or the final stop() sample) recorded counters
    assert any(ev[1] == "C" for ev in events)
    if kind == "disagg":
        assert snap["phase_transfer_p99_ms"] > 0.0


# ===========================================================================
# Gauges
# ===========================================================================

def test_sample_gauges_records_pool_cache_and_load_series(model):
    backend = make_backend(model, "disagg")
    sched = PagedLLMScheduler(backends=[backend])
    tracer = Tracer(clock=fake_clock())
    sample_gauges(tracer, sched)
    counters = {ev[2]: ev[6] for ev in tracer.events() if ev[1] == "C"}
    name = backend.name
    assert f"{name}:pool" in counters
    assert f"{name}:prefill_pool" in counters      # disagg staging pool
    assert {"pages_in_use", "num_free",
            "cow_headroom"} <= set(counters[f"{name}:pool"])
    assert counters[f"{name}:load"]["queued"] == 0
    assert counters[f"{name}:load"]["inflight_chunks"] == 0
    assert "decoding" in counters[f"{name}:load"]
    assert f"{name}:logit_cache" in counters
    assert counters[f"{name}:prewarm"]["residents"] >= 0


def test_sample_gauges_disabled_is_noop(model):
    backend = make_backend(model, "inproc")
    sched = PagedLLMScheduler(backends=[backend])
    sample_gauges(NULL_TRACER, sched)              # must not raise


# ===========================================================================
# Flight recorder + metrics tracing bridge
# ===========================================================================

def _req(rid=1, admitted=1.0, started=1.5, first=2.5, finished=3.0,
         transfer=0.0, model_id=0, deadline=100.0):
    req = Request(rid=rid, x=np.zeros(4, np.int32), arrival_t=admitted,
                  deadline_t=deadline, params=SamplingParams())
    req.model_id = model_id
    req.admitted_t = admitted
    req.started_t = started
    req.first_token_t = first
    req.transfer_wait_s = transfer
    req.finished_t = finished      # terminal helpers below overwrite this
    return req


def test_flight_recorder_trips_on_failure_and_rate_limits(tmp_path):
    path = tmp_path / "flight.json"
    tracer = Tracer(clock=fake_clock(), flight_recorder_path=str(path),
                    flight_recorder_min_interval_s=1e9)
    metrics = SchedulerMetrics([1.0])
    metrics.bind_tracer(tracer)
    req = _req()
    req.fail(RuntimeError("boom"), 3.0)
    metrics.on_fail(req)
    assert tracer.trips == 1 and tracer.dumps == 1
    payload = json.loads(path.read_text())
    assert validate_chrome_trace(payload) == []
    assert payload["otherData"]["reason"] == "request_failed"
    # a failure storm inside the min interval counts but doesn't re-dump
    req2 = _req(rid=2)
    req2.fail(RuntimeError("boom"), 3.5)
    metrics.on_fail(req2)
    assert tracer.trips == 2 and tracer.dumps == 1


def test_flight_recorder_manual_dump_windows_events(tmp_path):
    tracer = Tracer(clock=lambda: 100.0)
    tracer.instant("old", t=10.0)
    tracer.instant("recent", t=95.0)
    path = tracer.flight_recorder_dump(str(tmp_path / "dump.json"),
                                       window_s=20.0)
    payload = json.loads((tmp_path / "dump.json").read_text())
    assert path == str(tmp_path / "dump.json")
    names = {ev["name"] for ev in payload["traceEvents"]
             if ev["ph"] == "i"}
    assert names == {"recent"}


def test_slo_violation_trips_and_instants_flow_to_snapshot():
    tracer = Tracer(clock=fake_clock())
    metrics = SchedulerMetrics([1.0, 2.0])
    metrics.bind_tracer(tracer)
    late = _req(deadline=2.0)                      # finished_t=3.0 > deadline
    late.complete(np.zeros(4), 3.0)
    metrics.on_complete(late)
    assert tracer.trips == 1                       # no path: count only
    metrics.on_degrade(_req(rid=2), 1, 0)
    metrics.on_shed(_req(rid=3))
    snap = metrics.snapshot(now=4.0)
    assert snap["trace_instants"]["degrade"] == 1
    assert snap["trace_instants"]["shed"] == 1
    # the request chain itself also flowed through the consumer
    assert snap["trace_instants"]["ADMIT"] == 1
    assert snap["trace"]["recorded"] > 0


# ===========================================================================
# Metrics satellites: rejected queue, attribution, seeds, lifecycle
# ===========================================================================

def test_rejected_queue_wait_is_kept_out_of_served_percentiles():
    metrics = SchedulerMetrics([1.0])
    cancelled = _req()                  # admitted 1.0, started 1.5
    cancelled.cancel(2.0)
    metrics.on_cancel(cancelled)
    failed = _req(rid=2, admitted=1.0, started=0.0, first=0.0)
    failed.fail(RuntimeError("x"), 1.4)     # failed while still queued
    metrics.on_fail(failed)
    snap = metrics.snapshot(now=3.0)
    assert snap["rejected_count"] == 2
    assert snap["rejected_queue_p50_ms"] > 0.0
    assert len(metrics.queue_lat) == 0      # served percentiles untouched
    # a hard shed never queued (admitted_t == 0): counted by on_shed's
    # budget_exceeded, not as a rejected queue wait
    shed = _req(rid=3, admitted=0.0, started=0.0, first=0.0)
    shed.fail(RuntimeError("shed"), 1.0)
    metrics.on_shed(shed)
    metrics.on_fail(shed)
    assert metrics.snapshot(now=3.0)["rejected_count"] == 2


def test_phase_attribution_decomposes_end_to_end_latency():
    metrics = SchedulerMetrics([1.0])
    req = _req(admitted=1.0, started=1.5, first=2.5, finished=3.0,
               transfer=0.25)
    req.complete(np.zeros(4), 3.0)
    metrics.on_complete(req)
    snap = metrics.snapshot(now=4.0)
    assert snap["phase_queue_p50_ms"] == pytest.approx(500.0)
    assert snap["phase_prefill_p50_ms"] == pytest.approx(750.0)
    assert snap["phase_transfer_p50_ms"] == pytest.approx(250.0)
    assert snap["phase_decode_p50_ms"] == pytest.approx(500.0)
    # phases tile admission -> finish exactly
    total = sum(snap[f"phase_{p}_p50_ms"]
                for p in ("queue", "prefill", "transfer", "decode"))
    assert total == pytest.approx((req.finished_t - req.admitted_t) * 1e3)
    assert snap["ttft_p50_ms_by_model"][0] == pytest.approx(1500.0)


def test_per_model_itl_reservoirs():
    metrics = SchedulerMetrics([1.0, 2.0])
    metrics.on_decode_gap(1, 0.010)
    snap = metrics.snapshot(now=1.0)
    assert snap["itl_p50_ms"] == pytest.approx(10.0)
    assert snap["itl_p50_ms_by_model"] == [0.0, pytest.approx(10.0)]


def test_reservoirs_get_distinct_seeds():
    metrics = SchedulerMetrics([1.0, 2.0])
    reservoirs = [metrics.queue_lat, metrics.service_lat, metrics.total_lat,
                  metrics.ttft_lat, metrics.itl_lat,
                  metrics.rejected_queue_lat,
                  *metrics.phase_lat.values(), *metrics.ttft_by_model,
                  *metrics.itl_by_model, *metrics.backend_queue_wait,
                  *metrics.transfer_lat]
    states = [r._rng.getstate() for r in reservoirs]
    assert len({str(s) for s in states}) == len(states), \
        "same-seeded reservoirs evict correlated slots"


def test_snapshot_elapsed_mid_run_and_across_restarts():
    metrics = SchedulerMetrics([1.0], clock=lambda: 1e9)
    metrics.on_start(100.0)
    mid = metrics.snapshot(now=105.0)
    assert mid["elapsed_s"] == pytest.approx(5.0)    # live: runs to now
    metrics.on_stop(110.0)
    assert metrics.snapshot(now=999.0)["elapsed_s"] == pytest.approx(10.0)
    metrics.on_start(200.0)                          # restart accumulates
    assert metrics.snapshot(now=207.0)["elapsed_s"] == pytest.approx(17.0)
    req = _req()
    req.complete(np.zeros(4), 3.0)
    metrics.on_complete(req)
    snap = metrics.snapshot(now=205.0)
    assert snap["throughput_rps"] == pytest.approx(1.0 / 15.0)
