"""Paged KV cache: pool accounting, paged<->ring decode parity (per
step, across full/window/chunked/GQA/MLA), page reclaim, int8 pages,
and the token-level continuous-decode scheduler."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import transformer as tf
from repro.serving.engine import Engine, ServeConfig
from repro.serving.kv_cache import OutOfPages, PagePool
from repro.serving.scheduler import PagedLLMConfig, PagedLLMScheduler


def tiny_config(variant: str, kv_cache_dtype: str = "float32") -> ModelConfig:
    kw = dict(name=f"tiny-{variant}", arch_type="dense", num_layers=2,
              d_model=32, d_ff=64, vocab_size=64, num_heads=4,
              num_kv_heads=2, head_dim=8, compute_dtype="float32",
              param_dtype="float32", kv_cache_dtype=kv_cache_dtype)
    if variant == "full":
        kw["pattern"] = (LayerSpec(attn_kind="full"),)
    elif variant == "swa":
        kw["pattern"] = (LayerSpec(attn_kind="swa"),)
        kw["window"] = 6
    elif variant == "chunked":
        kw["pattern"] = (LayerSpec(attn_kind="chunked"),)
        kw["chunk"] = 5
    elif variant == "gqa_mixed":
        kw["pattern"] = (LayerSpec(attn_kind="full"),
                         LayerSpec(attn_kind="swa"))
        kw["window"] = 6
        kw["num_kv_heads"] = 1          # MQA
    elif variant == "mla":
        kw["pattern"] = (LayerSpec(mixer="mla"),)
        kw.update(num_heads=2, q_lora=16, kv_lora=8, d_nope=8, d_rope=4,
                  v_head_dim=8)
    else:
        raise ValueError(variant)
    return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------

def test_page_pool_accounting():
    pool = PagePool(num_pages=6, page_size=4)
    assert pool.num_free == 5            # page 0 is scratch
    a = pool.alloc(2)
    b = pool.alloc(2)
    assert not set(a) & set(b) and 0 not in a + b
    assert pool.pages_in_use == 4 and pool.peak_in_use == 4
    pool.free(a)
    assert pool.num_free == 3
    c = pool.alloc(3)                    # reuses a's pages
    assert set(a) <= set(c)
    assert pool.peak_in_use == 5
    with pytest.raises(OutOfPages):
        pool.alloc(1)
    pool.free(b)
    pool.free(c)
    assert pool.pages_in_use == 0 and pool.num_free == 5
    with pytest.raises(ValueError):
        pool.free(b)                     # double free
    d = pool.alloc(1)
    with pytest.raises(ValueError):
        pool.free(d + [99])              # foreign page: nothing mutates ...
    assert pool.pages_in_use == 1        # ... so d stays held
    with pytest.raises(ValueError):
        pool.free([d[0], d[0]])          # duplicate ids in one call
    assert pool.pages_in_use == 1
    pool.free(d)
    assert pool.pages_for(1) == 1 and pool.pages_for(4) == 1
    assert pool.pages_for(5) == 2
    row = pool.block_table([3, 1], max_pages=4)
    np.testing.assert_array_equal(row, [3, 1, 0, 0])


def test_page_pool_refcounts():
    """Refcount semantics under sharing: incref'd pages survive decref
    by one holder, return to the free list only at zero, and the COW
    headroom tracks writable shared pages (see test_pool_property.py
    for the randomized harness over the same invariants)."""
    pool = PagePool(num_pages=8, page_size=4)
    a = pool.alloc(3)
    assert [pool.refcount(pg) for pg in a] == [1, 1, 1]
    pool.incref(a[:2])                       # a second holder maps 2 pages
    assert pool.refcount(a[0]) == 2
    assert pool.pages_in_use == 3            # unique pages, shared count once
    assert pool.shared_pages == 2
    pool.mark_cow_risk(a[1])
    assert pool.cow_headroom == 1
    pool.decref(a)                           # first holder retires
    assert pool.pages_in_use == 2 and pool.num_free == 5
    assert pool.cow_headroom == 0            # exclusive again: no copy due
    with pytest.raises(ValueError):
        pool.incref([a[2]])                  # free page cannot be increfed
    pool.decref(a[:2])                       # last holder retires
    assert pool.pages_in_use == 0 and pool.num_free == 7
    assert pool.refcount(a[0]) == 0


# ---------------------------------------------------------------------------
# Paged <-> ring numerical parity, per decode step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant",
                         ["full", "swa", "chunked", "gqa_mixed", "mla"])
def test_paged_matches_ring_per_step(variant):
    cfg = tiny_config(variant)
    key = jax.random.key(3)
    params = tf.init_params(cfg, key)
    b, s, p, ps = 1, 18, 7, 4
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    logits_r, ring = tf.prefill(params, cfg, tokens[:, :p], cache_len=s,
                                cache_dtype=jnp.float32)
    m = -(-s // ps)
    paged = tf.init_caches(cfg, 0, 0, jnp.float32, num_pages=m + 1,
                           page_size=ps)
    bt = jnp.arange(1, m + 1, dtype=jnp.int32)[None]
    logits_p, paged = tf.prefill_paged(params, cfg, tokens[:, :p], paged, bt,
                                       last_index=p - 1)
    np.testing.assert_allclose(np.asarray(logits_r), np.asarray(logits_p),
                               atol=2e-5)
    for i in range(p, s):
        logits_r, ring = tf.decode_step(params, cfg, tokens[:, i:i + 1],
                                        ring, i)
        logits_p, paged = tf.decode_step(params, cfg, tokens[:, i:i + 1],
                                         paged, jnp.asarray([i]),
                                         block_tables=bt)
        np.testing.assert_allclose(np.asarray(logits_r), np.asarray(logits_p),
                                   atol=3e-5, err_msg=f"{variant} pos={i}")


def test_mixed_length_batch_matches_solo():
    """Two requests at different positions decode in ONE paged batch;
    each row matches the same request decoded alone (token-level
    continuous batching is numerically per-row)."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=48))
    pool = eng.init_paged(num_pages=30, page_size=4, decode_batch=4)
    key = jax.random.key(9)
    p1 = np.asarray(jax.random.randint(key, (6,), 0, cfg.vocab_size))
    p2 = np.asarray(jax.random.randint(jax.random.fold_in(key, 1), (13,), 0,
                                       cfg.vocab_size))
    ref1 = eng.generate_paged(p1, max_new_tokens=8)["tokens"]
    ref2 = eng.generate_paged(p2, max_new_tokens=6)["tokens"]

    s1 = eng.prefill_into_pages(p1, max_new_tokens=8)
    eng.decode_step_batch([s1])
    eng.decode_step_batch([s1])          # s1 is 2 tokens ahead ...
    s2 = eng.prefill_into_pages(p2, max_new_tokens=6)  # ... when s2 joins
    while not (s1.done and s2.done):
        eng.decode_step_batch([s for s in (s1, s2) if not s.done])
    eng.pool.free(s1.pages)
    eng.pool.free(s2.pages)
    np.testing.assert_array_equal(np.concatenate([p1, s1.tokens]), ref1)
    np.testing.assert_array_equal(np.concatenate([p2, s2.tokens]), ref2)
    assert pool.pages_in_use == 0


def test_page_reclaim_reuse_identical_output():
    """After a request finishes its pages are immediately reusable, and
    a follow-up request landing on the reclaimed (dirty) pages produces
    the exact same output as on a fresh pool."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    # pool fits exactly one request: reuse is forced
    pool = eng.init_paged(num_pages=5, page_size=4, decode_batch=2)
    key = jax.random.key(2)
    pa = np.asarray(jax.random.randint(key, (9,), 0, cfg.vocab_size))
    pb = np.asarray(jax.random.randint(jax.random.fold_in(key, 1), (9,), 0,
                                       cfg.vocab_size))
    out_a = eng.generate_paged(pa, max_new_tokens=7)["tokens"]
    assert pool.pages_in_use == 0
    out_b = eng.generate_paged(pb, max_new_tokens=7)["tokens"]    # dirty pages
    out_a2 = eng.generate_paged(pa, max_new_tokens=7)["tokens"]   # dirtier
    np.testing.assert_array_equal(out_a, out_a2)
    assert not np.array_equal(out_a, out_b)   # actually different requests
    assert pool.pages_in_use == 0 and pool.peak_in_use == 4


def test_capacity_and_pool_errors():
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=16))
    prompts = jnp.zeros((1, 10), jnp.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(prompts, max_new_tokens=10)
    eng.init_paged(num_pages=4, page_size=4, decode_batch=2)
    with pytest.raises(ValueError, match="max_len"):
        eng.prefill_into_pages(np.zeros((10,), np.int32), max_new_tokens=10)
    with pytest.raises(OutOfPages, match="exhausted"):
        eng.prefill_into_pages(np.zeros((10,), np.int32), max_new_tokens=6)
    assert eng.pool.pages_in_use == 0    # failed admission leaked nothing
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.prefill_into_pages(np.zeros((4,), np.int32), max_new_tokens=0)


def test_int8_paged_pool():
    """kv_cache_dtype=int8 threads through the paged allocator: pages
    are stored quantized and decode stays within quantisation error of
    the float pool."""
    cfg8 = tiny_config("full", kv_cache_dtype="int8")
    params = tf.init_params(cfg8, jax.random.key(0))
    s, p, ps = 16, 6, 4
    m = -(-s // ps)
    tokens = jax.random.randint(jax.random.key(4), (1, s), 0, cfg8.vocab_size)
    bt = jnp.arange(1, m + 1, dtype=jnp.int32)[None]

    caches8 = tf.init_caches(cfg8, 0, 0, num_pages=m + 1, page_size=ps)
    leaf = caches8["p0"]["k"]
    assert leaf.dtype == jnp.int8
    assert "k_scale" in caches8["p0"]
    cachesf = tf.init_caches(cfg8, 0, 0, jnp.float32, num_pages=m + 1,
                             page_size=ps)
    l8, caches8 = tf.prefill_paged(params, cfg8, tokens[:, :p], caches8, bt,
                                   last_index=p - 1)
    lf, cachesf = tf.prefill_paged(params, cfg8, tokens[:, :p], cachesf, bt,
                                   last_index=p - 1)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(lf), atol=0.15)
    for i in range(p, s):
        l8, caches8 = tf.decode_step(params, cfg8, tokens[:, i:i + 1],
                                     caches8, jnp.asarray([i]),
                                     block_tables=bt)
        lf, cachesf = tf.decode_step(params, cfg8, tokens[:, i:i + 1],
                                     cachesf, jnp.asarray([i]),
                                     block_tables=bt)
        np.testing.assert_allclose(np.asarray(l8), np.asarray(lf), atol=0.15,
                                   err_msg=f"pos={i}")


def test_sampled_generation_batch_independent():
    """temperature > 0: a request's sampled tokens are a function of
    (seed, prompt) alone — repeatable across calls and identical
    whether it decodes solo or continuously batched with others."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=32, temperature=0.7))
    eng.init_paged(num_pages=16, page_size=4, decode_batch=2)
    pa = np.arange(5) % cfg.vocab_size
    pb = (np.arange(7) * 3) % cfg.vocab_size
    out_a = eng.generate_paged(pa, max_new_tokens=6)["tokens"]
    np.testing.assert_array_equal(
        out_a, eng.generate_paged(pa, max_new_tokens=6)["tokens"])
    out_b = eng.generate_paged(pb, max_new_tokens=6)["tokens"]
    s1 = eng.prefill_into_pages(pa, max_new_tokens=6)
    s2 = eng.prefill_into_pages(pb, max_new_tokens=6)
    while not (s1.done and s2.done):
        eng.decode_step_batch([s for s in (s1, s2) if not s.done])
    eng.pool.free(s1.pages)
    eng.pool.free(s2.pages)
    np.testing.assert_array_equal(np.concatenate([pa, s1.tokens]), out_a)
    np.testing.assert_array_equal(np.concatenate([pb, s2.tokens]), out_b)


def test_warmup_page_padded_length_at_max_len():
    """warmup must not trip the capacity check when a prompt length
    page-pads up to max_len (regression: pages_for(30)*8 == max_len)."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    eng.init_paged(num_pages=20, page_size=8, decode_batch=2)
    sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=2))
    sched.warmup([30])
    assert eng.pool.pages_in_use == 0


def test_paged_rejects_mamba():
    cfg = ModelConfig(name="ssm", arch_type="ssm", num_layers=1, d_model=16,
                      d_ff=32, vocab_size=32,
                      pattern=(LayerSpec(mixer="mamba"),),
                      d_inner=32, ssm_state=4, dt_rank=4)
    with pytest.raises(NotImplementedError):
        tf.init_caches(cfg, 0, 0, num_pages=4, page_size=4)


# ---------------------------------------------------------------------------
# Token-level continuous-decode scheduler
# ---------------------------------------------------------------------------

def test_scheduler_continuous_decode_trace():
    """A staggered mixed-length trace through PagedLLMScheduler: every
    output matches the solo-decoded reference, at least one decode
    batch mixes requests admitted at different times, and the pool
    drains back to zero pages in use."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=40, page_size=4, decode_batch=4)
    key = jax.random.key(5)
    lens = [5, 11, 17, 8]
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                             (l,), 0, cfg.vocab_size))
               for i, l in enumerate(lens)]
    refs = [eng.generate_paged(p, max_new_tokens=10)["tokens"]
            for p in prompts]

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=10))
        sched.warmup(lens)
        async with sched:
            handles = [sched.submit(prompts[0]),
                       sched.submit(prompts[1])]
            # let the first two get ahead so the later admissions join a
            # *running* decode batch
            while sched.decode_batches < 2:
                await asyncio.sleep(0.005)
            handles += [sched.submit(prompts[2]),
                        sched.submit(prompts[3])]
            outs = await asyncio.gather(*handles)
        return sched, outs

    sched, outs = asyncio.run(main())
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)
    snap = sched.snapshot()
    assert snap["completed"] == 4 and snap["failed"] == 0
    assert snap["mixed_admission_batches"] >= 1
    assert snap["pools"][0]["pages_in_use"] == 0
    assert snap["pools"][0]["peak_pages_in_use"] > 0
    assert snap["tokens_generated"] >= 4 * 10 - 4   # first tokens from prefill


def test_stop_without_drain_reclaims_pages():
    """Cancelling a scheduler mid-generation must hand the stranded
    sequences' pages back to the pool — the engine outlives the
    scheduler and would otherwise serve with shrunken capacity."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=20, page_size=4, decode_batch=2)

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=40))
        await sched.start()
        handle = sched.submit(np.zeros((8,), np.int32))
        while sched.decode_batches < 1:     # request is mid-generation
            await asyncio.sleep(0.005)
        await sched.stop(drain=False)
        assert handle.done()
        return sched

    asyncio.run(main())
    assert eng.pool.pages_in_use == 0


def test_paged_lifecycle_restart_and_double_start():
    """SchedulerLifecycle regression on the token-level runtime:
    double start raises, a stopped scheduler rejects submissions, and
    the same instance restarts cleanly and serves again."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    eng.init_paged(num_pages=12, page_size=4, decode_batch=2)
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    ref = eng.generate_paged(prompt, max_new_tokens=4)["tokens"]

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=4))
        await sched.start()
        with pytest.raises(RuntimeError, match="already started"):
            await sched.start()
        out1 = await sched.submit(prompt)
        await sched.stop()
        await sched.stop()                   # idempotent
        with pytest.raises(RuntimeError, match="not running"):
            sched.submit_nowait(prompt)
        async with sched:                    # restart the same instance
            out2 = await sched.submit(prompt)
        return out1, out2

    out1, out2 = asyncio.run(main())
    np.testing.assert_array_equal(out1, ref)
    np.testing.assert_array_equal(out2, ref)
    assert eng.pool.pages_in_use == 0


def test_paged_lifecycle_drain_then_cancel_mid_decode():
    """drain() leaves nothing inflight; a later no-drain stop mid
    generation fails the stranded future AND returns its pages —
    cancel-mid-decode must not shrink the engine's pool."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=20, page_size=4, decode_batch=2)

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=30))
        await sched.start()
        fut1 = sched.submit(np.zeros(4, np.int32), max_new_tokens=2).future
        await sched.drain()
        assert fut1.done() and not fut1.cancelled()
        fut2 = sched.submit(np.zeros(8, np.int32)).future
        while sched.decode_batches < 2:      # provably mid-generation
            await asyncio.sleep(0.005)
        await sched.stop(drain=False)
        # the stranded future is resolved one way or the other —
        # cancelled by stop, or failed by the reclamation hook
        assert fut2.done()
        if not fut2.cancelled():
            with pytest.raises(RuntimeError, match="stopped before"):
                fut2.result()

    asyncio.run(main())
    assert eng.pool.pages_in_use == 0


def test_scheduler_backpressure_oversized_request():
    """A request larger than the whole pool fails fast; one that merely
    has to wait for pages completes once earlier requests retire."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(max_len=32))
    eng.init_paged(num_pages=6, page_size=4, decode_batch=2)  # 20 tokens
    key = jax.random.key(6)
    small = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                           (6,), 0, cfg.vocab_size))
             for i in range(3)]
    refs = [eng.generate_paged(p, max_new_tokens=6)["tokens"] for p in small]

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=6))
        async with sched:
            # 3 x 12 tokens = 3 pages each; pool holds 5 -> the third
            # waits for reclaimed pages.  (submit_nowait here doubles
            # as the paged compat-shim pin.)
            handles = [sched.submit(p) for p in small]
            too_big = sched.submit_nowait(
                np.zeros((26,), np.int32), max_new_tokens=6)
            outs = await asyncio.gather(*handles)
            with pytest.raises(OutOfPages):
                await too_big
        return sched, outs

    sched, outs = asyncio.run(main())
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)
    assert sched.snapshot()["pools"][0]["pages_in_use"] == 0


class _FakeChunkBackend:
    """capacity()-only stand-in so the adaptive-chunk policy can be
    unit-tested without a device worker loop."""

    def capacity(self):
        from repro.serving.backend import BackendCapacity
        return BackendCapacity(decode_batch=4, page_size=4, num_pages=16,
                               free_pages=16)

    def bind_metrics(self, metrics, model_id):
        pass

    def bind_tracer(self, tracer):
        pass


def _policy_sched(tracer=None, **cfg_kw):
    cfg = PagedLLMConfig(prefill_chunk_pages=2, adaptive_chunk=True,
                         min_chunk_pages=1, max_chunk_pages=8,
                         chunk_slack=4.0, **cfg_kw)
    return PagedLLMScheduler(backends=[_FakeChunkBackend()], cfg=cfg,
                             clock=lambda: 0.0, tracer=tracer)


def _join(sched, deadline_t, max_new=10, generated=0):
    from types import SimpleNamespace
    from repro.serving.scheduler.request import Request, SamplingParams
    req = Request(rid=1, x=None, arrival_t=0.0, deadline_t=deadline_t,
                  params=SamplingParams(max_new_tokens=max_new))
    return sched.slots[0].join(req, SimpleNamespace(tokens=[0] * generated),
                               admit_step=0)


def test_adaptive_chunk_policy_slo_slack():
    """SLO-aware chunk sizing: idle backend -> ceiling; no inter-token
    evidence -> base; tight stream slack -> floor; generous -> ceiling;
    in-between -> base.  (base=2, lo=1, hi=8 pages; itl p50 = 10ms;
    thresholds at 4*base*itl = 80ms and 4*hi*itl = 320ms of slack.)"""
    sched = _policy_sched()
    assert sched._adaptive_chunk_pages(0) == 8       # nothing decoding
    ent = _join(sched, deadline_t=0.15)
    assert sched._adaptive_chunk_pages(0) == 2       # no itl evidence yet
    for _ in range(8):
        sched.metrics.itl_by_model[0].add(0.010)
    # slack = 0.15 - 10 remaining tokens * 10ms = 50ms < 80ms -> floor
    assert sched._adaptive_chunk_pages(0) == 1
    sched.slots[0].retire(ent)
    _join(sched, deadline_t=1.0)                     # slack 900ms -> ceiling
    assert sched._adaptive_chunk_pages(0) == 8
    ent3 = _join(sched, deadline_t=0.3)              # tightest rules: 200ms
    assert sched._adaptive_chunk_pages(0) == 2       # between -> base
    ent3.seq.tokens.extend([0] * 5)                  # 5 left: slack 250ms
    assert sched._adaptive_chunk_pages(0) == 2


def test_adaptive_chunk_policy_measured_stalls():
    """Once >=5 chunks have been measured, the policy sizes against the
    per-page stall distribution's p90 instead of the one-page-per-decode
    heuristic: largest of {lo, base, hi} whose chunk_slack-padded stall
    fits the tightest stream's slack.  (5ms/page p90, margin 4x ->
    hi=8 needs 160ms, base=2 needs 40ms, lo=1 needs 20ms of slack.)"""
    sched = _policy_sched()
    ent = _join(sched, deadline_t=0.3)
    for _ in range(8):
        sched.metrics.itl_by_model[0].add(0.010)
    # under 5 samples -> no evidence -> heuristic (slack 200ms: base)
    for _ in range(4):
        sched.metrics.on_chunk_stall(0, 2, 0.010)
    assert sched.metrics.chunk_stall_per_page(0) is None
    assert sched._adaptive_chunk_pages(0) == 2
    sched.metrics.on_chunk_stall(0, 2, 0.010)        # 5th sample
    assert sched.metrics.chunk_stall_per_page(0) == pytest.approx(0.005)
    # measured policy kicks in: slack 200ms >= 160ms -> ceiling
    assert sched._adaptive_chunk_pages(0) == 8
    sched.slots[0].retire(ent)
    _join(sched, deadline_t=0.2)                     # slack 100ms -> base
    assert sched._adaptive_chunk_pages(0) == 2
    _join(sched, deadline_t=0.13)                    # slack 30ms -> floor
    assert sched._adaptive_chunk_pages(0) == 1
    _join(sched, deadline_t=0.105)                   # slack 5ms: nothing
    assert sched._adaptive_chunk_pages(0) == 1       # fits -> still floor
    snap = sched.metrics.snapshot()
    assert snap["chunk_stall_page_p90_ms"][0] == pytest.approx(5.0)


def test_chunk_stall_measurement_guards():
    """Degenerate measurements never poison the policy: zero-page calls
    are dropped, and all-zero durations (fake clocks) leave the policy
    on the heuristic path rather than dividing slack by zero."""
    sched = _policy_sched()
    sched.metrics.on_chunk_stall(0, 0, 0.010)        # dropped
    assert len(sched.metrics.chunk_stall_page[0]) == 0
    for _ in range(6):
        sched.metrics.on_chunk_stall(0, 1, 0.0)
    assert sched.metrics.chunk_stall_per_page(0) == 0.0
    _join(sched, deadline_t=1.0)
    for _ in range(8):
        sched.metrics.itl_by_model[0].add(0.010)
    # per-page 0.0 -> measured branch skipped -> heuristic ceiling
    assert sched._adaptive_chunk_pages(0) == 8


def test_auto_chunk_bounds_follow_stall_distribution():
    """auto_chunk_bounds re-tunes the policy's (lo, hi) from the
    measured per-page stall distribution: a heavy tail (p90 > 2x p50)
    narrows to (1, base), a tight one (p90 within 25% of p50) widens
    to (base, hi), in-between keeps the config bounds — and before
    there is evidence the config bounds stand."""
    sched = _policy_sched(auto_chunk_bounds=True)
    assert sched._chunk_bounds(0) == (1, 8)          # no evidence yet
    for _ in range(8):                               # heavy tail: 20x ratio
        sched.metrics.on_chunk_stall(0, 1, 0.001)
    for _ in range(2):
        sched.metrics.on_chunk_stall(0, 1, 0.020)
    assert sched._chunk_bounds(0) == (1, 2)          # narrow to (1, base)
    # the narrowed ceiling binds the whole policy: idle -> hi -> base
    assert sched._adaptive_chunk_pages(0) == 2

    tight = _policy_sched(auto_chunk_bounds=True)
    for _ in range(10):                              # ratio 1.0
        tight.metrics.on_chunk_stall(0, 1, 0.010)
    assert tight._chunk_bounds(0) == (2, 8)          # widen to (base, hi)
    assert tight._adaptive_chunk_pages(0) == 8       # idle -> ceiling

    mid = _policy_sched(auto_chunk_bounds=True)
    for _ in range(8):                               # ratio 1.5: in-between
        mid.metrics.on_chunk_stall(0, 1, 0.010)
    for _ in range(2):
        mid.metrics.on_chunk_stall(0, 1, 0.015)
    assert mid._chunk_bounds(0) == (1, 8)            # config bounds stand
    # default (auto off) never consults the distribution at all
    fixed = _policy_sched()
    for _ in range(10):
        fixed.metrics.on_chunk_stall(0, 1, 0.001)
    fixed.metrics.on_chunk_stall(0, 1, 0.050)
    assert fixed._chunk_bounds(0) == (1, 8)


def test_auto_chunk_bounds_warmup_compiles_single_page():
    """The warmup ladder always includes the one-page chunk shape when
    auto_chunk_bounds is on — the tuned floor may narrow to a single
    page mid-serve and must already be compiled."""

    class _WarmupRecorder(_FakeChunkBackend):
        def __init__(self):
            self.chunk_tokens = []

        def warmup(self, prompt_lens, chunk_tokens=None):
            self.chunk_tokens.append(chunk_tokens)

    def ladder(auto):
        b = _WarmupRecorder()
        PagedLLMScheduler(
            backends=[b], clock=lambda: 0.0,
            cfg=PagedLLMConfig(prefill_chunk_pages=2, adaptive_chunk=True,
                               min_chunk_pages=2, max_chunk_pages=8,
                               auto_chunk_bounds=auto)).warmup([8])
        return b.chunk_tokens

    assert ladder(auto=False) == [8, 32]             # base + hi (ps=4)
    assert ladder(auto=True) == [8, 4, 32]           # + the 1-page shape


def test_next_chunk_tokens_traces_counter():
    """_next_chunk_tokens converts the policy's pages to tokens and
    exposes the choice as the 'chunk_pages' tracer counter; with
    adaptive_chunk off it returns the static base size untraced."""
    from repro.serving.observability.tracer import COUNTER, Tracer
    tracer = Tracer()
    sched = _policy_sched(tracer=tracer)
    assert sched._next_chunk_tokens(0) == 8 * 4      # idle -> hi pages
    _join(sched, deadline_t=0.01)
    for _ in range(8):
        sched.metrics.itl_by_model[0].add(0.010)
    assert sched._next_chunk_tokens(0) == 1 * 4      # floor, page_size=4
    counts = [e for e in tracer.events()
              if e[1] == COUNTER and e[2] == "chunk_pages"]
    assert [c[6]["m0"] for c in counts] == [8, 1]
    static = PagedLLMScheduler(backends=[_FakeChunkBackend()],
                               cfg=PagedLLMConfig(prefill_chunk_pages=2),
                               clock=lambda: 0.0)
    assert static._next_chunk_tokens(0) == 2 * 4
    off = PagedLLMScheduler(backends=[_FakeChunkBackend()],
                            cfg=PagedLLMConfig())
    assert off._next_chunk_tokens(0) is None
