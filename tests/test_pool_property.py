"""Hypothesis property tests for the refcounted PagePool under random
interleaved alloc / share / grow / copy-on-write / decref / cancel op
sequences, and for the prefix index under random prompt traffic.

The op set mirrors the serving stack's whole page lifecycle: ``alloc``
is a serial admission, ``grow`` is a chunked-prefill step allocating
the next chunk's pages onto a live sequence, ``share`` is a
prefix-sharing join, ``cow`` a copy-on-write, ``release`` a normal
retire, and ``cancel`` a mid-flight abort (streaming API) that must
restore the pool to the sequence's pre-admission unique-page count.
Speculative decoding adds three more: ``draft`` grows provisional
pages a verify round may throw away, ``accept`` commits them, and
``rollback`` is the rejected-draft reconcile — a refcounted decref of
every page above the kept boundary, exactly what
``Engine.rollback_pages`` does to a draft cache.

Invariants (the ownership contract the prefix-sharing serving stack
leans on):
  * refcount(page) always equals the number of holders — no page is
    ever double-owned at refcount 1;
  * pages_in_use + num_free is conserved at num_pages - 1;
  * the scratch page is never handed out;
  * allocation is lowest-id deterministic: replaying an op trace on a
    fresh pool yields identical page assignments — with speculative
    draft/accept/rollback interleaved with COW and cancel;
  * a cancel of a partially-grown sequence frees exactly the unique
    pages that sequence held — including mid-verify, with draft pages
    outstanding;
  * a rollback frees exactly the dropped pages this sequence held
    exclusively (shared holders keep theirs);
  * after every sequence retires the pool drains to zero pages held,
    zero prefix entries, zero COW headroom — nothing leaks, rejected
    drafts included.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("pool-ci", max_examples=40, deadline=None)
    settings.load_profile("pool-ci")
except ImportError:
    # the @given property tests skip; the fixed-trace replay tests —
    # same interpreter, same invariants — still run
    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None
    st = _NoStrategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

from repro.models.attention import SCRATCH_PAGE
from repro.serving.kv_cache import OutOfPages, PagePool
from repro.serving.kv_host_tier import HostTier, TieredPagePool


class SimSeq:
    """Shadow model of one sequence's page holdings."""

    def __init__(self, pages):
        self.pages = list(pages)
        self.prefix_keys = []
        self.spec_mark = None       # page count before outstanding drafts


def apply_op(pool: PagePool, live, op):
    """One deterministic interpreter for both the generation pass and
    the replay pass (determinism is asserted between the two)."""
    kind = op[0]
    if kind == "alloc":
        live.append(SimSeq(pool.alloc(op[1])))
    elif kind == "grow":
        # a chunked-prefill step: a live (mid-prefill) sequence
        # allocates the next chunk's pages onto what it already holds
        live[op[1]].pages.extend(pool.alloc(op[2]))
    elif kind == "share":
        # a prefix-sharing join: the new sequence maps the same pages;
        # its (now shared) boundary page may later need copy-on-write
        src = live[op[1]]
        pool.incref(src.pages)
        pool.mark_cow_risk(src.pages[-1])
        live.append(SimSeq(src.pages))
    elif kind == "cow":
        seq = live[op[1]]
        old = seq.pages[op[2]]
        new = pool.alloc(1)[0]
        pool.decref([old])
        seq.pages[op[2]] = new
    elif kind == "draft":
        # speculative draft: provisional pages grown past the committed
        # boundary; a later accept keeps them, a rollback decrefs them
        seq = live[op[1]]
        if seq.spec_mark is None:
            seq.spec_mark = len(seq.pages)
        seq.pages.extend(pool.alloc(op[2]))
    elif kind == "accept":
        # verify round accepted the drafts: they become committed pages
        live[op[1]].spec_mark = None
    elif kind == "rollback":
        # verify round rejected drafts past op[2]: refcounted decref of
        # the dropped span — only pages this sequence held exclusively
        # come back to the free list
        seq = live[op[1]]
        dropped = seq.pages[op[2]:]
        del seq.pages[op[2]:]
        before = pool.pages_in_use
        exclusive = sum(1 for pg in set(dropped)
                        if pool.refcount(pg) == 1 and pg not in seq.pages)
        pool.decref(dropped)
        assert pool.pages_in_use == before - exclusive
        seq.spec_mark = None
    elif kind == "release":
        pool.release(live.pop(op[1]))
    elif kind == "cancel":
        # a mid-flight abort (handle.cancel()): release must return
        # the pool to this sequence's pre-admission unique-page count
        # — exactly the pages only it holds come back
        seq = live.pop(op[1])
        before = pool.pages_in_use
        exclusive = sum(1 for pg in set(seq.pages)
                        if pool.refcount(pg) == 1)
        pool.release(seq)
        assert pool.pages_in_use == before - exclusive
    else:
        raise AssertionError(op)


def run_trace(pool: PagePool, trace):
    live = []
    for op in trace:
        apply_op(pool, live, op)
    return live


def check_invariants(pool: PagePool, live):
    assert pool.pages_in_use + pool.num_free == pool.num_pages - 1
    holders = {}
    for seq in live:
        for pg in seq.pages:
            assert pg != SCRATCH_PAGE
            holders[pg] = holders.get(pg, 0) + 1
    assert pool.pages_in_use == len(holders)
    for pg, n in holders.items():
        assert pool.refcount(pg) == n     # no double-own at refcount 1
    assert pool.peak_in_use >= pool.pages_in_use
    assert pool.cow_headroom <= pool.num_free + pool.pages_in_use


@given(st.data())
def test_pool_random_alloc_share_cow_decref(data):
    num_pages = data.draw(st.integers(4, 20), label="num_pages")
    pool = PagePool(num_pages=num_pages, page_size=4)
    live, trace = [], []
    for _ in range(data.draw(st.integers(1, 30), label="steps")):
        ops = []
        if pool.num_free:
            ops.append("alloc")
        if live:
            ops.append("share")
            ops.append("release")
            ops.append("cancel")
        if live and pool.num_free:
            ops.append("grow")
        if live and pool.num_free and any(
                pool.refcount(pg) > 1 for s in live for pg in s.pages):
            ops.append("cow")
        if live and pool.num_free:
            ops.append("draft")
        specced = [i for i, s in enumerate(live) if s.spec_mark is not None]
        if specced:
            ops.append("accept")
            ops.append("rollback")
        kind = data.draw(st.sampled_from(sorted(ops)), label="op")
        if kind == "alloc":
            n = data.draw(st.integers(1, pool.num_free), label="n")
            op = ("alloc", n)
        elif kind == "grow":
            op = ("grow", data.draw(st.integers(0, len(live) - 1),
                                    label="seq"),
                  data.draw(st.integers(1, pool.num_free), label="n"))
        elif kind == "share":
            op = ("share", data.draw(st.integers(0, len(live) - 1),
                                     label="seq"))
        elif kind == "cow":
            cands = [(i, j) for i, s in enumerate(live)
                     for j, pg in enumerate(s.pages)
                     if pool.refcount(pg) > 1]
            op = ("cow",) + data.draw(st.sampled_from(cands), label="page")
        elif kind == "draft":
            op = ("draft", data.draw(st.integers(0, len(live) - 1),
                                     label="seq"),
                  data.draw(st.integers(1, pool.num_free), label="n"))
        elif kind == "accept":
            op = ("accept", data.draw(st.sampled_from(specced), label="seq"))
        elif kind == "rollback":
            i = data.draw(st.sampled_from(specced), label="seq")
            # keep anywhere from the committed boundary (full rejection)
            # to everything (k accepted, nothing to roll back)
            op = ("rollback", i,
                  data.draw(st.integers(live[i].spec_mark,
                                        len(live[i].pages)), label="keep"))
        elif kind == "cancel":
            op = ("cancel", data.draw(st.integers(0, len(live) - 1),
                                      label="seq"))
        else:
            op = ("release", data.draw(st.integers(0, len(live) - 1),
                                       label="seq"))
        apply_op(pool, live, op)
        trace.append(op)
        check_invariants(pool, live)

    # determinism: the same trace on a fresh pool hands out the same
    # lowest-id pages in the same order
    pool2 = PagePool(num_pages=num_pages, page_size=4)
    live2 = run_trace(pool2, trace)
    assert [s.pages for s in live2] == [s.pages for s in live]
    assert pool2.pages_in_use == pool.pages_in_use

    # zero leaks once everything retires
    for seq in list(live):
        pool.release(seq)
    assert pool.pages_in_use == 0
    assert pool.num_free == num_pages - 1
    assert pool.prefix_entries == 0
    assert pool.cow_headroom == 0
    assert SCRATCH_PAGE not in pool._free           # scratch never freed
    assert pool.refcount(SCRATCH_PAGE) == 0         # and never held


@given(st.data())
def test_prefix_index_random_prompt_traffic(data):
    """Register/lookup/release under random prompts from a tiny
    alphabet (forcing prefix collisions): lookups only ever return
    resident pages covering a page-aligned (or whole-prompt) prefix,
    empty prompts index nothing, and the index drains with the pool."""
    ps = data.draw(st.sampled_from([2, 4]), label="page_size")
    pool = PagePool(num_pages=24, page_size=ps)
    live = []
    for _ in range(data.draw(st.integers(1, 20), label="steps")):
        if live and data.draw(st.booleans(), label="retire"):
            pool.release(live.pop(data.draw(
                st.integers(0, len(live) - 1), label="seq")))
        else:
            toks = np.asarray(data.draw(
                st.lists(st.integers(0, 2), min_size=0, max_size=3 * ps),
                label="prompt"), np.int32)
            mapped, matched = pool.lookup_prefix(toks)
            assert matched <= len(toks)
            assert matched % ps == 0 or matched == len(toks)
            assert len(mapped) == -(-matched // ps)
            for pg in mapped:
                assert pool.refcount(pg) >= 1
            total = pool.pages_for(len(toks))
            assert total == -(-len(toks) // ps)     # 0 tokens -> 0 pages
            if total - len(mapped) > pool.num_free:
                continue                    # backpressure: skip admission
            pool.incref(mapped)
            pages = list(mapped) + pool.alloc(total - len(mapped))
            seq = SimSeq(pages)
            seq.prefix_keys = pool.register_prefix(toks, pages)
            assert len(seq.prefix_keys) <= len(pages)
            if len(toks) == 0:
                assert seq.prefix_keys == [] and pages == []
            live.append(seq)
        assert pool.pages_in_use + pool.num_free == pool.num_pages - 1
    for seq in list(live):
        pool.release(seq)
    assert pool.pages_in_use == 0
    assert pool.prefix_entries == 0
    assert pool.num_free == pool.num_pages - 1


# ---------------------------------------------------------------------------
# KV memory hierarchy: TieredPagePool retention / spill / restore
# ---------------------------------------------------------------------------

def make_tiered(num_pages=16, host_pages=8, watermark=0.0) -> TieredPagePool:
    pool = TieredPagePool(num_pages=num_pages, page_size=4,
                          host_tier=HostTier(host_pages, page_size=4),
                          spill_watermark=watermark)
    # bookkeeping-only stand-in for Engine._spill_pages: the harness
    # checks ownership accounting, not KV bytes, so every "gathered"
    # package is a fixed-shape zero slab
    pool.bind_spill(lambda pages: np.zeros((1, 8, 4, 1), np.float32), 8)
    return pool


def tiered_admit(pool: TieredPagePool, live, toks,
                 cancel_restore: bool = False):
    """The engine's admission flow against the memory hierarchy:
    device-resident prefix maps (incref), the host tier continues the
    chain (alloc + consume — or, ``cancel_restore``, the mid-restore
    cancellation: the freshly-allocated pages decref and the host
    entries survive untouched), and the remainder allocates fresh.
    Any OutOfPages rolls the whole admission back — the pool must
    return to its pre-admission state."""
    toks = np.asarray(toks, np.int32)
    mapped, _matched = pool.lookup_prefix(toks)
    pool.incref(mapped)
    pages = list(mapped)
    try:
        run = pool.host_tier.lookup(toks, start_chunk=len(mapped))
        if run:
            new = pool.alloc(len(run))
            if cancel_restore:
                # scatter failed / request cancelled mid-restore: the
                # device pages hand back, the host copies stay intact
                pool.decref(new)
            else:
                pool.host_tier.consume([k for k, _s, _p in run])
                pages += new
        pages += pool.alloc(pool.pages_for(len(toks)) - len(pages))
    except OutOfPages:
        pool.decref(pages)          # roll back: mapped increfs + restores
        return None
    seq = SimSeq(pages)
    seq.prefix_keys = pool.register_prefix(toks, pages)
    live.append(seq)
    return seq


def check_tiered_invariants(pool: TieredPagePool, live):
    """Cross-tier ownership: every held device page is accounted for by
    live holders plus at most one retention claim (refcount
    conservation across tiers); the host tier's slot map is coherent;
    the scratch page is never handed out; page conservation holds."""
    assert pool.pages_in_use + pool.num_free == pool.num_pages - 1
    holders = {}
    for seq in live:
        for pg in seq.pages:
            assert pg != SCRATCH_PAGE
            holders[pg] = holders.get(pg, 0) + 1
    retained = set(pool._retained)
    assert pool.pages_in_use == len(set(holders) | retained)
    for pg in set(holders) | retained:
        assert pool.refcount(pg) == (holders.get(pg, 0)
                                     + (1 if pg in retained else 0))
    assert pool.retained_pages == len(retained)
    assert pool.spillable_pages == sum(
        1 for pg in retained if pool.refcount(pg) == 1)
    tier = pool.host_tier
    assert tier.pages_in_use == len(tier._slot_keys) <= tier.num_pages
    assert sum(len(ks) for ks in tier._slot_keys.values()) \
        == len(tier._entries)
    for key, slot in tier._entries.items():
        assert key in tier._slot_keys[slot]


@given(st.data())
def test_tiered_pool_random_retain_spill_restore(data):
    """Random admit / retire / spill / restore traffic over the memory
    hierarchy, prompts drawn from a tiny alphabet so chunk chains
    collide: refcounts stay conserved across tiers, mid-restore
    cancellation leaks nothing, and after every retirement plus a full
    eviction sweep the device pool drains to zero."""
    num_pages = data.draw(st.integers(6, 16), label="num_pages")
    host_pages = data.draw(st.integers(0, 8), label="host_pages")
    pool = make_tiered(num_pages=num_pages, host_pages=host_pages)
    live = []
    for _ in range(data.draw(st.integers(1, 25), label="steps")):
        ops = ["admit"]
        if live:
            ops.append("retire")
        if pool.retained_pages:
            ops.append("spill")
        kind = data.draw(st.sampled_from(sorted(ops)), label="op")
        if kind == "admit":
            toks = data.draw(st.lists(st.integers(0, 2), min_size=1,
                                      max_size=12), label="prompt")
            cancel = data.draw(st.booleans(), label="cancel_restore")
            before = pool.pages_in_use
            if tiered_admit(pool, live, toks, cancel_restore=cancel) is None:
                # rollback leaks nothing — in-use can only have DROPPED
                # (the failing alloc may have evicted cold retention as
                # a side effect before coming up short)
                assert pool.pages_in_use <= before
        elif kind == "retire":
            pool.release(live.pop(data.draw(
                st.integers(0, len(live) - 1), label="seq")))
        else:
            pool.drop_retained()
        check_tiered_invariants(pool, live)

    for seq in list(live):
        pool.release(seq)
    pool.drop_retained()
    check_tiered_invariants(pool, [])
    assert pool.pages_in_use == 0
    assert pool.num_free == pool.num_pages - 1
    assert pool.prefix_entries == 0
    assert pool.refcount(SCRATCH_PAGE) == 0


def test_tiered_fixed_trace_spill_restore_cancel():
    """Deterministic floor for the tiered ops (runs without
    hypothesis): retention takes over a retiring prompt's pages,
    eviction under demand spills refcount-1 pages to the host (the
    device page FREES — never resident in both tiers), a restore
    consumes the host copy, a cancelled restore leaks nothing and
    leaves the host copy intact, and a shared retained page drops
    without ever spilling."""
    pool = make_tiered(num_pages=10, host_pages=8)
    tier = pool.host_tier
    live = []

    toks = [1, 1, 2, 2, 3, 3, 4, 4, 5]          # 2 full chunks + partial
    s0 = tiered_admit(pool, live, toks)
    assert [pool.refcount(pg) for pg in s0.pages] == [1, 1, 1]
    pool.release(live.pop(0))
    assert pool.retained_pages == 3 and pool.pages_in_use == 3

    # demand eviction: allocating past free capacity spills the
    # retained pages — and frees them on-device (single-tier residency)
    spilled = list(pool._retained)
    big = SimSeq(pool.alloc(9)); live.append(big)
    assert pool.retained_pages == 0
    # the spilled pages FREED on-device (single-tier residency) — the
    # 9-page alloc could only succeed by reusing them
    assert set(spilled) <= set(big.pages) and pool.pages_in_use == 9
    assert tier.pages_in_use == 3 and pool.stats()["pages_spilled"] == 3
    pool.release(live.pop(0))               # big held no prefix: all free
    assert pool.pages_in_use == 0

    # cancelled restore: device pages hand back, host copies intact
    before = tier.stats()["restored_pages"]
    s1 = tiered_admit(pool, live, toks, cancel_restore=True)
    assert tier.pages_in_use == 3                   # host untouched
    assert tier.stats()["restored_pages"] == before
    check_tiered_invariants(pool, live)
    pool.release(live.pop(0))
    pool.drop_retained()                            # stale dup copies drop

    # committed restore: host entries consume, pages come back exact
    s2 = tiered_admit(pool, live, toks)
    assert tier.pages_in_use == 0                   # consumed on restore
    assert tier.stats()["restored_pages"] >= 3
    check_tiered_invariants(pool, live)

    # a retained page a live sequence still maps must drop, not spill
    s3 = tiered_admit(pool, live, toks)             # shares s2's pages
    pool.release(live.pop(0))                       # retire s2: retained,
    assert pool.retained_pages == 3                 # but s3 still maps them
    assert pool.spillable_pages == 0
    spilled_before = tier.stats()["spilled_pages"]
    assert pool.drop_retained() == 0                # frees nothing
    assert tier.stats()["spilled_pages"] == spilled_before
    check_tiered_invariants(pool, live)

    pool.release(live.pop(0))
    pool.drop_retained()
    assert pool.pages_in_use == 0 and pool.prefix_entries == 0
    assert pool.num_free == pool.num_pages - 1


def test_spec_draft_rollback_fixed_trace():
    """Deterministic spec-decode lifecycle through the same interpreter
    the property test drives (and a guaranteed-covered floor for its
    draft ops): draft pages interleave with prefix shares, COW, and
    mid-verify cancellation; every rollback decref frees exactly the
    exclusively-held span; replay on a fresh pool is bit-identical; and
    the pool drains to zero with rejected drafts in the history."""
    trace = [
        ("alloc", 3),           # s0: three committed pages
        ("draft", 0, 2),        # s0 drafts two provisional pages
        ("share", 0),           # s1 joins mid-verify, sharing the drafts
        ("rollback", 0, 4),     # s0 rejects its last draft page — s1
                                # still holds it, so nothing frees yet
        ("cancel", 1),          # s1 aborts mid-verify: the orphaned
                                # draft page must come back now
        ("alloc", 2),           # s1': fresh stream
        ("draft", 1, 3),
        ("accept", 1),          # verify accepted: drafts are committed
        ("draft", 1, 2),
        ("rollback", 1, 5),     # full rejection of the second round
        ("share", 0),           # s2 shares s0's surviving pages
        ("cow", 2, 2),          # s2 copy-on-writes a shared page
        ("draft", 2, 1),
        ("cancel", 2),          # cancel with a draft outstanding
    ]
    pool = PagePool(num_pages=12, page_size=4)
    live = []
    for op in trace:
        apply_op(pool, live, op)
        check_invariants(pool, live)

    pool2 = PagePool(num_pages=12, page_size=4)
    live2 = run_trace(pool2, trace)
    assert [s.pages for s in live2] == [s.pages for s in live]
    assert pool2.pages_in_use == pool.pages_in_use

    for seq in list(live):
        pool.release(seq)
    assert pool.pages_in_use == 0
    assert pool.num_free == pool.num_pages - 1
    assert pool.prefix_entries == 0
    assert pool.cow_headroom == 0
    assert pool.refcount(SCRATCH_PAGE) == 0
