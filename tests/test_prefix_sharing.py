"""Prefix-sharing COW-correctness contract: identical prompts (and
shared-prefix batches) generate token-identical outputs with sharing
on vs off, across full/window/chunked/GQA/MLA paged variants and under
forced-Pallas interpret mode; copy-on-write never lets one request's
decode tokens leak into another's prefix."""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import transformer as tf
from repro.serving.engine import Engine, ServeConfig
from repro.serving.kv_cache import PagePool
from repro.serving.scheduler import PagedLLMConfig, PagedLLMScheduler

PS = 4          # page size everywhere here: small so prefixes span pages


def tiny_config(variant: str) -> ModelConfig:
    kw = dict(name=f"share-{variant}", arch_type="dense", num_layers=2,
              d_model=32, d_ff=64, vocab_size=64, num_heads=4,
              num_kv_heads=2, head_dim=8, compute_dtype="float32",
              param_dtype="float32", kv_cache_dtype="float32")
    if variant == "full":
        kw["pattern"] = (LayerSpec(attn_kind="full"),)
    elif variant == "swa":
        kw["pattern"] = (LayerSpec(attn_kind="swa"),)
        kw["window"] = 6
    elif variant == "chunked":
        kw["pattern"] = (LayerSpec(attn_kind="chunked"),)
        kw["chunk"] = 5
    elif variant == "gqa_mixed":
        kw["pattern"] = (LayerSpec(attn_kind="full"),
                         LayerSpec(attn_kind="swa"))
        kw["window"] = 6
        kw["num_kv_heads"] = 1          # MQA
    elif variant == "mla":
        kw["pattern"] = (LayerSpec(mixer="mla"),)
        kw.update(num_heads=2, q_lora=16, kv_lora=8, d_nope=8, d_rope=4,
                  v_head_dim=8)
    else:
        raise ValueError(variant)
    return ModelConfig(**kw)


def make_engine(cfg, params, sharing: bool, num_pages: int = 40) -> Engine:
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=num_pages, page_size=PS, decode_batch=4,
                   prefix_sharing=sharing)
    return eng


def prompts_with_shared_prefix(cfg, prefix_len=8, tails=(3, 5), seed=7):
    key = jax.random.key(seed)
    prefix = np.asarray(jax.random.randint(key, (prefix_len,), 0,
                                           cfg.vocab_size))
    out = []
    for i, t in enumerate(tails):
        tail = np.asarray(jax.random.randint(jax.random.fold_in(key, i + 1),
                                             (t,), 0, cfg.vocab_size))
        out.append(np.concatenate([prefix, tail]))
    return out


# ---------------------------------------------------------------------------
# Parity: sharing on vs off, all paged variants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant",
                         ["full", "swa", "chunked", "gqa_mixed", "mla"])
def test_shared_prefix_parity_on_vs_off(variant):
    """A follower request that maps a resident's 2-page prefix and
    prefills only its tail generates exactly the tokens a no-sharing
    engine produces — for every paged attention variant."""
    cfg = tiny_config(variant)
    params = tf.init_params(cfg, jax.random.key(3))
    pa, pb = prompts_with_shared_prefix(cfg)
    off = make_engine(cfg, params, sharing=False)
    ref_a = off.generate_paged(pa, max_new_tokens=6)["tokens"]
    ref_b = off.generate_paged(pb, max_new_tokens=6)["tokens"]

    on = make_engine(cfg, params, sharing=True)
    sa = on.prefill_into_pages(pa, max_new_tokens=6)
    sb = on.prefill_into_pages(pb, max_new_tokens=6)
    assert sa.shared_prefix_len == 0            # first resident: no match
    assert sb.shared_prefix_len == 8            # 2 aligned pages mapped
    assert sb.pages[:2] == sa.pages[:2]         # same physical pages
    assert all(on.pool.refcount(pg) == 2 for pg in sa.pages[:2])
    while not (sa.done and sb.done):
        on.decode_step_batch([s for s in (sa, sb) if not s.done])
    np.testing.assert_array_equal(np.concatenate([pa, sa.tokens]), ref_a)
    np.testing.assert_array_equal(np.concatenate([pb, sb.tokens]), ref_b)
    on.pool.release(sa)
    on.pool.release(sb)
    assert on.pool.pages_in_use == 0 and on.pool.prefix_entries == 0


@pytest.mark.parametrize("variant", ["full", "mla"])
def test_identical_prompt_decode_cow_parity(variant):
    """Two identical unaligned prompts share every prompt page
    including the partially-filled boundary page; the first decode
    insert into it copy-on-writes, and both generations stay
    token-identical to the no-sharing reference."""
    cfg = tiny_config(variant)
    params = tf.init_params(cfg, jax.random.key(4))
    p = np.asarray(jax.random.randint(jax.random.key(9), (10,), 0,
                                      cfg.vocab_size))       # 10 % 4 = 2
    off = make_engine(cfg, params, sharing=False)
    ref = off.generate_paged(p, max_new_tokens=6)["tokens"]

    on = make_engine(cfg, params, sharing=True)
    a = on.prefill_into_pages(p, max_new_tokens=6)
    b = on.prefill_into_pages(p, max_new_tokens=6)
    assert b.shared_prefix_len == 9             # p - 1: only the final
    assert b.pages[:3] == a.pages[:3]           # token is recomputed
    boundary = a.pages[2]
    assert on.pool.refcount(boundary) == 2
    assert on.pool.cow_headroom == 1            # admission held 1 page back
    on.decode_step_batch([a, b])                # both insert at pos 10
    assert on.cow_count == 1                    # exactly one private copy
    assert on.pool.refcount(boundary) == 1
    assert a.pages[2] != b.pages[2]
    while not (a.done and b.done):
        on.decode_step_batch([s for s in (a, b) if not s.done])
    np.testing.assert_array_equal(np.concatenate([p, a.tokens]), ref)
    np.testing.assert_array_equal(np.concatenate([p, b.tokens]), ref)
    on.pool.release(a)
    on.pool.release(b)
    assert on.pool.pages_in_use == 0 and on.pool.cow_headroom == 0


def test_shared_batch_vs_solo():
    """A shared-prefix pair decoding in ONE batch matches each request
    decoded solo on a fresh no-sharing pool (sharing is invisible to
    the numerics, not just to the final argmax winner)."""
    cfg = tiny_config("gqa_mixed")
    params = tf.init_params(cfg, jax.random.key(5))
    pa, pb = prompts_with_shared_prefix(cfg, prefix_len=12, tails=(2, 6),
                                        seed=11)
    off = make_engine(cfg, params, sharing=False)
    refs = [off.generate_paged(x, max_new_tokens=8)["tokens"]
            for x in (pa, pb)]
    on = make_engine(cfg, params, sharing=True)
    sa = on.prefill_into_pages(pa, max_new_tokens=8)
    on.decode_step_batch([sa])
    on.decode_step_batch([sa])                  # sa is mid-generation ...
    sb = on.prefill_into_pages(pb, max_new_tokens=8)  # ... when sb joins
    assert sb.shared_prefix_len == 12
    while not (sa.done and sb.done):
        on.decode_step_batch([s for s in (sa, sb) if not s.done])
    np.testing.assert_array_equal(np.concatenate([pa, sa.tokens]), refs[0])
    np.testing.assert_array_equal(np.concatenate([pb, sb.tokens]), refs[1])
    on.pool.release(sa)
    on.pool.release(sb)
    assert on.pool.pages_in_use == 0


def test_parity_under_forced_pallas_interpret(monkeypatch):
    """The COW contract holds when decode runs through the Pallas
    paged-attention kernel (interpret mode on CPU): shared-prefix and
    identical-prompt generations match the no-sharing engine."""
    from repro.kernels import ops as kops
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(6))
    pa, pb = prompts_with_shared_prefix(cfg, prefix_len=8, tails=(2, 2),
                                        seed=13)
    off = make_engine(cfg, params, sharing=False)
    on = make_engine(cfg, params, sharing=True)
    monkeypatch.setattr(kops, "_FORCE", "interpret")
    ref_a = off.generate_paged(pa, max_new_tokens=4)["tokens"]
    ref_b = off.generate_paged(pb, max_new_tokens=4)["tokens"]
    sa = on.prefill_into_pages(pa, max_new_tokens=4)
    sb = on.prefill_into_pages(pb, max_new_tokens=4)
    assert sb.shared_prefix_len == 8
    while not (sa.done and sb.done):
        on.decode_step_batch([s for s in (sa, sb) if not s.done])
    np.testing.assert_array_equal(np.concatenate([pa, sa.tokens]), ref_a)
    np.testing.assert_array_equal(np.concatenate([pb, sb.tokens]), ref_b)
    on.pool.release(sa)
    on.pool.release(sb)
    assert on.pool.pages_in_use == 0


def test_cow_is_fused_into_decode_step_trace():
    """COW runs INSIDE the decode jit (one compiled program copies the
    boundary page and inserts the token): the trace must show a 'cow'
    instant with fused=True and NO standalone copy_page span — a
    separate copy dispatch would be the old two-program round trip."""
    from repro.serving.observability.tracer import INSTANT, Tracer
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(4))
    p = np.asarray(jax.random.randint(jax.random.key(9), (10,), 0,
                                      cfg.vocab_size))
    on = make_engine(cfg, params, sharing=True)
    on.tracer = tracer = Tracer()
    a = on.prefill_into_pages(p, max_new_tokens=2)
    b = on.prefill_into_pages(p, max_new_tokens=2)
    on.decode_step_batch([a, b])                # COW fires here
    assert on.cow_count == 1
    evs = tracer.events()
    cows = [e for e in evs if e[2] == "cow" and e[1] == INSTANT]
    assert len(cows) == 1
    assert cows[0][6]["fused"] is True
    assert not [e for e in evs if "copy_page" in e[2]]
    on.pool.release(a)
    on.pool.release(b)


# ---------------------------------------------------------------------------
# Semantics around the edges
# ---------------------------------------------------------------------------

def test_sharing_noop_on_unaligned_divergence():
    """Prompts that diverge inside the first page share nothing —
    the index is page-aligned by design (documented no-op)."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    on = make_engine(cfg, params, sharing=True)
    pa = np.asarray([1, 2, 3, 4, 5, 6, 7, 8])
    pb = np.asarray([1, 2, 9, 4, 5, 6, 7, 8])   # differs at token 2
    sa = on.prefill_into_pages(pa, max_new_tokens=2)
    sb = on.prefill_into_pages(pb, max_new_tokens=2)
    assert sb.shared_prefix_len == 0
    assert not set(sa.pages) & set(sb.pages)
    on.pool.release(sa)
    on.pool.release(sb)
    assert on.pool.pages_in_use == 0


def test_release_after_sharer_retires_keeps_pages_alive():
    """Retiring the original resident decrefs but must not free pages
    a follower still maps; the follower keeps generating correctly and
    the pool drains only when the last holder releases."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(1))
    off = make_engine(cfg, params, sharing=False)
    pa, pb = prompts_with_shared_prefix(cfg, seed=17)
    ref_b = off.generate_paged(pb, max_new_tokens=6)["tokens"]
    on = make_engine(cfg, params, sharing=True)
    sa = on.prefill_into_pages(pa, max_new_tokens=6)
    sb = on.prefill_into_pages(pb, max_new_tokens=6)
    shared = list(sb.pages[:2])
    on.pool.release(sa)                          # original retires first
    assert all(on.pool.refcount(pg) == 1 for pg in shared)
    while not sb.done:
        on.decode_step_batch([sb])
    np.testing.assert_array_equal(np.concatenate([pb, sb.tokens]), ref_b)
    on.pool.release(sb)
    assert on.pool.pages_in_use == 0 and on.pool.prefix_entries == 0


def test_pool_zero_token_and_empty_free_edges():
    """pages_for(0) is 0 (an empty sequence holds nothing), negative
    sizes raise, decref([]) / free([]) are no-ops, and the prefix index
    never creates entries for empty prompts."""
    pool = PagePool(num_pages=6, page_size=4)
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    with pytest.raises(ValueError, match=">= 0"):
        pool.pages_for(-1)
    pool.free([])                                # documented no-op
    pool.decref([])
    assert pool.pages_in_use == 0 and pool.num_free == 5
    assert pool.register_prefix(np.zeros((0,), np.int32), []) == []
    assert pool.lookup_prefix(np.zeros((0,), np.int32)) == ([], 0)
    assert pool.prefix_entries == 0


def test_scheduler_admission_budgets_unique_pages():
    """A pool too small for two private copies serves a shared-prefix
    pair concurrently: admission charges only unique pages, outputs
    match solo references, and the trace provably overlapped."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(2))
    # each request: 12 prompt + 4 new = 16 tokens = 4 pages; two private
    # copies need 8 pages but only 6 are allocatable -> only sharing
    # (4 + 2 unique) lets the pair run together
    pa, pb = prompts_with_shared_prefix(cfg, prefix_len=8, tails=(4, 4),
                                        seed=19)
    off = make_engine(cfg, params, sharing=False, num_pages=7)
    refs = [off.generate_paged(x, max_new_tokens=4)["tokens"]
            for x in (pa, pb)]
    eng = make_engine(cfg, params, sharing=True, num_pages=7)

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=4))
        async with sched:
            handles = [sched.submit(pa), sched.submit(pb)]
            outs = await asyncio.gather(*handles)
        return sched, outs

    sched, outs = asyncio.run(main())
    np.testing.assert_array_equal(outs[0], refs[0])
    np.testing.assert_array_equal(outs[1], refs[1])
    snap = sched.snapshot()
    assert snap["completed"] == 2 and snap["failed"] == 0
    assert snap["prefill_tokens_shared"] == 8    # pb mapped the prefix
    assert snap["pools"][0]["peak_pages_in_use"] == 6   # 4 + 2 unique
    assert snap["pools"][0]["pages_in_use"] == 0
