"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.contrastive import cosine_distance
from repro.kernels.ref import mux_score_ref
from repro.launch import hlo_cost
from repro.models.layers import apply_rope, rms_norm, softcap
from repro.models.moe import route, init_moe

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

floats = st.floats(-50.0, 50.0, allow_nan=False, width=32)


@given(st.lists(floats, min_size=4, max_size=16),
       st.floats(1.0, 100.0, allow_nan=False))
def test_softcap_bounds_and_monotone(xs, cap):
    x = jnp.asarray(xs, jnp.float32)
    y = softcap(x, cap)
    assert float(jnp.abs(y).max()) <= cap + 1e-4
    order = jnp.argsort(x)
    assert bool(jnp.all(jnp.diff(y[order]) >= -1e-5))


@given(st.integers(1, 8), st.integers(2, 64))
def test_rms_norm_unit_rms(b, d):
    x = jax.random.normal(jax.random.key(b * 100 + d), (b, d)) * 10 + 1
    y = rms_norm(x, jnp.ones((d,)))
    rms = jnp.sqrt(jnp.mean(jnp.square(y.astype(jnp.float32)), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


@given(st.integers(0, 4), st.integers(1, 64))
def test_rope_preserves_norm_and_zero_position_identity(seed, pos):
    key = jax.random.key(seed)
    x = jax.random.normal(key, (1, 1, 2, 16))
    positions = jnp.array([[pos]])
    y = apply_rope(x, positions)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y)),
                               np.linalg.norm(np.asarray(x)), rtol=1e-5)
    y0 = apply_rope(x, jnp.array([[0]]))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x), atol=1e-6)


@given(st.integers(0, 10))
def test_cosine_distance_range_and_self(seed):
    key = jax.random.key(seed)
    e = jax.random.normal(key, (4, 8))
    e = e / jnp.linalg.norm(e, axis=-1, keepdims=True)
    d_self = cosine_distance(e, e)
    assert float(d_self.max()) <= 2e-4 + 1e-4
    e2 = jax.random.normal(jax.random.fold_in(key, 1), (4, 8))
    e2 = e2 / jnp.linalg.norm(e2, axis=-1, keepdims=True)
    d = cosine_distance(e, e2)
    assert float(d.min()) >= 0.0 and float(d.max()) <= 1.0


@given(st.integers(1, 6), st.integers(2, 10), st.integers(0, 5))
def test_mux_score_is_distribution(b, n, seed):
    key = jax.random.key(seed)
    meta = jax.random.normal(key, (b, 12))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, 12))
    cost = jnp.arange(1.0, n + 1.0)
    w = mux_score_ref(meta, v, cost)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert float(w.min()) >= 0.0


@given(st.integers(2, 8), st.integers(1, 4), st.integers(0, 3),
       st.sampled_from(["softmax_topk", "topk_softmax", "sigmoid"]))
def test_router_topk_invariants(e, k, seed, act):
    if k > e:
        return
    key = jax.random.key(seed)
    params = init_moe(key, d_model=8, num_experts=e, moe_d_ff=4)
    x = jax.random.normal(key, (2, 6, 8))
    w, idx, aux = route(params, x, num_experts=e, top_k=k, router_act=act)
    assert idx.shape == (2, 6, k)
    assert int(idx.min()) >= 0 and int(idx.max()) < e
    # top-k experts are distinct per token
    for row in np.asarray(idx).reshape(-1, k):
        assert len(set(row.tolist())) == k
    assert float(w.min()) >= 0.0
    assert float(aux) >= 0.0


@given(st.sampled_from(["f32", "bf16", "s32", "u8"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_hlo_type_bytes_parser(dt, dims):
    bytes_per = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1}[dt]
    n = int(np.prod(dims)) if dims else 1
    s = f"{dt}[{','.join(map(str, dims))}]{{0}}"
    elems, byts = hlo_cost._shape_elems_bytes(s)
    assert elems == n
    assert byts == n * bytes_per
