"""Overflow and capacity-edge semantics of the model-level dispatch
(repro.core.routing) plus the selection/padding primitives the serving
scheduler shares with it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import routing


def _x(b, d=4, seed=0):
    return jax.random.normal(jax.random.key(seed), (b, d))


# ---------------------------------------------------------------------------
# bucket_by_model / dispatch / combine
# ---------------------------------------------------------------------------

def test_no_overflow_when_capacity_covers_batch():
    assign = jnp.array([2, 0, 1, 1, 0, 2, 1, 0])
    plan = routing.bucket_by_model(assign, num_models=3, capacity=8)
    assert bool(jnp.all(plan["kept"]))
    x = _x(8)
    buckets = routing.dispatch(x, plan, 3, 8)
    out = routing.combine(buckets, plan)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_overflow_drops_excess_and_fills():
    # 5 requests all want model 1; capacity 2 keeps exactly 2
    assign = jnp.array([1, 1, 1, 1, 1])
    plan = routing.bucket_by_model(assign, num_models=3, capacity=2)
    assert int(plan["kept"].sum()) == 2
    x = _x(5)
    buckets = routing.dispatch(x, plan, 3, 2)
    # dropped requests land in the overflow slot, not in any bucket
    out = routing.combine(buckets, plan, fill_value=-7.0)
    kept = np.asarray(plan["kept"])
    np.testing.assert_array_equal(np.asarray(out)[~kept],
                                  np.full((3, 4), -7.0))
    np.testing.assert_array_equal(np.asarray(out)[kept],
                                  np.asarray(x)[kept])


def test_capacity_below_fair_share():
    # B=9 over N=3 models, capacity 1 < B/N: at most one kept per model
    assign = jnp.array([0, 0, 0, 1, 1, 1, 2, 2, 2])
    plan = routing.bucket_by_model(assign, num_models=3, capacity=1)
    kept = np.asarray(plan["kept"])
    assert kept.sum() == 3
    for m in range(3):
        assert kept[np.asarray(assign) == m].sum() == 1


def test_combine_round_trip_identity_per_model():
    assign = jnp.array([0, 1, 0, 2, 1])
    x = _x(5)
    plan = routing.bucket_by_model(assign, 3, 4)
    buckets = routing.dispatch(x, plan, 3, 4)
    # each bucket holds its model's requests in arrival order
    for m in range(3):
        mine = np.asarray(x)[np.asarray(assign) == m]
        np.testing.assert_array_equal(np.asarray(buckets[m])[:len(mine)],
                                      mine)
    out = routing.combine(buckets, plan)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_multiplexed_apply_overflow_kept_flags():
    x = _x(6)
    assign = jnp.array([0, 0, 0, 0, 1, 1])
    fns = [lambda b: b * 2.0, lambda b: b * 3.0]
    out, kept = routing.multiplexed_apply(x, assign, fns, capacity=2)
    kept = np.asarray(kept)
    assert kept.sum() == 4               # 2 kept per model
    scale = np.where(np.asarray(assign) == 0, 2.0, 3.0)[:, None]
    np.testing.assert_allclose(np.asarray(out)[kept],
                               (np.asarray(x) * scale)[kept])


# ---------------------------------------------------------------------------
# pad_bucket: device path vs host mirror
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,capacity", [(1, 4), (3, 4), (4, 4), (6, 4)])
def test_pad_bucket_host_matches_device(k, capacity):
    x = _x(k, seed=k)
    bucket_dev, valid_dev = routing.pad_bucket(x, capacity)
    bucket_host, valid_host = routing.pad_bucket_host(list(np.asarray(x)),
                                                      capacity)
    np.testing.assert_array_equal(np.asarray(bucket_dev), bucket_host)
    np.testing.assert_array_equal(np.asarray(valid_dev), valid_host)
    # row i of the bucket is request i (order preserved)
    n_real = min(k, capacity)
    np.testing.assert_array_equal(bucket_host[:n_real],
                                  np.asarray(x)[:n_real])
    assert valid_host[:n_real].all() and not valid_host[n_real:].any()


def test_pad_bucket_host_rejects_empty():
    with pytest.raises(ValueError, match="at least one request"):
        routing.pad_bucket_host([], 4)


# ---------------------------------------------------------------------------
# select_model: argmax and thresholded hybrid selection
# ---------------------------------------------------------------------------

def test_select_model_argmax_default():
    w = jnp.array([[0.1, 0.7, 0.2], [0.6, 0.3, 0.1]])
    costs = jnp.array([1.0, 2.0, 4.0])
    np.testing.assert_array_equal(
        np.asarray(routing.select_model(w, costs)), [1, 0])


def test_select_model_threshold_prefers_cheapest():
    costs = jnp.array([1.0, 2.0, 4.0])
    w = jnp.array([
        [0.5, 0.3, 0.2],    # cheapest clears 0.4 -> 0
        [0.1, 0.45, 0.45],  # model 1 is cheapest above 0.4
        [0.2, 0.3, 0.5],    # only the largest clears -> 2
        [0.3, 0.3, 0.3],    # nobody clears -> fall back to largest
    ])
    np.testing.assert_array_equal(
        np.asarray(routing.select_model(w, costs, threshold=0.4)),
        [0, 1, 2, 2])


def test_select_model_threshold_unsorted_costs():
    # costs not in index order: cheapest is index 2
    costs = jnp.array([4.0, 2.0, 1.0])
    w = jnp.array([[0.45, 0.45, 0.45], [0.9, 0.05, 0.05]])
    sel = np.asarray(routing.select_model(w, costs, threshold=0.4))
    np.testing.assert_array_equal(sel, [2, 0])


def test_select_model_jit_traceable():
    costs = jnp.array([1.0, 2.0])
    f = jax.jit(lambda w: routing.select_model(w, costs, threshold=0.6))
    sel = f(jnp.array([[0.7, 0.3], [0.5, 0.5]]))
    np.testing.assert_array_equal(np.asarray(sel), [0, 1])
