"""Unit + integration tests for the continuous-batching mux scheduler
(repro.serving.scheduler)."""
import asyncio

import numpy as np
import pytest

from repro.serving.scheduler import (BatchingPolicy, MicroBatcher,
                                     ModelQueue, MuxScheduler,
                                     SchedulerConfig, SchedulerMetrics,
                                     TrafficConfig, arrival_times, replay)
from repro.serving.scheduler.request import Request, RequestState


def _req(rid, deadline_t, x=None):
    return Request(rid=rid, x=x if x is not None else np.zeros(2),
                   arrival_t=0.0, deadline_t=deadline_t)


# ---------------------------------------------------------------------------
# ModelQueue + MicroBatcher
# ---------------------------------------------------------------------------

def test_queue_pops_in_deadline_order():
    q = ModelQueue(0)
    for rid, dl in [(0, 5.0), (1, 1.0), (2, 3.0)]:
        q.push(_req(rid, dl), now=0.0)
    batcher = MicroBatcher(BatchingPolicy(max_batch_size=8))
    batch = batcher.form(q, now=0.0)
    assert [r.rid for r in batch] == [1, 2, 0]
    assert all(r.state is RequestState.BATCHED for r in batch)


def test_deadline_tie_breaks_fifo():
    q = ModelQueue(0)
    for rid in range(4):
        q.push(_req(rid, deadline_t=1.0), now=0.0)
    batch = MicroBatcher(BatchingPolicy(max_batch_size=8)).form(q, now=0.0)
    assert [r.rid for r in batch] == [0, 1, 2, 3]


def test_batch_full_triggers_ready():
    q = ModelQueue(0)
    batcher = MicroBatcher(BatchingPolicy(max_batch_size=3, max_wait_ms=1e9))
    for rid in range(2):
        q.push(_req(rid, 1.0), now=0.0)
    assert not batcher.ready(q, now=0.0)
    q.push(_req(2, 1.0), now=0.0)
    assert batcher.ready(q, now=0.0)


def test_max_wait_flushes_partial_batch():
    q = ModelQueue(0)
    batcher = MicroBatcher(BatchingPolicy(max_batch_size=8, max_wait_ms=5.0))
    q.push(_req(0, 1.0), now=10.0)
    assert not batcher.ready(q, now=10.001)          # 1ms old: wait
    assert batcher.ready(q, now=10.006)              # 6ms old: flush
    assert batcher.time_until_ready(q, now=10.001) == pytest.approx(0.004)
    assert batcher.time_until_ready(q, now=10.2) == 0.0
    assert batcher.time_until_ready(ModelQueue(1), now=0.0) is None


def test_form_respects_max_batch_size_and_leaves_rest():
    q = ModelQueue(0)
    for rid in range(5):
        q.push(_req(rid, deadline_t=float(rid)), now=0.0)
    batcher = MicroBatcher(BatchingPolicy(max_batch_size=3))
    batch = batcher.form(q, now=0.0)
    assert [r.rid for r in batch] == [0, 1, 2]
    assert len(q) == 2


def test_form_bucket_rows_follow_batch_order():
    batcher = MicroBatcher(BatchingPolicy(max_batch_size=4))
    batch = [_req(i, 1.0, x=np.full(3, float(i + 1))) for i in range(2)]
    bucket, valid = batcher.form_bucket(batch)
    assert bucket.shape == (4, 3)
    np.testing.assert_array_equal(bucket[0], np.full(3, 1.0))
    np.testing.assert_array_equal(bucket[1], np.full(3, 2.0))
    np.testing.assert_array_equal(bucket[2:], np.zeros((2, 3)))
    np.testing.assert_array_equal(valid, [True, True, False, False])


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_metrics_counters_and_eq14():
    m = SchedulerMetrics(costs=[1.0, 4.0])
    m.on_start(0.0)
    reqs = []
    for rid, model, t_done in [(0, 0, 0.010), (1, 0, 0.020), (2, 1, 0.030)]:
        r = _req(rid, deadline_t=1.0)
        r.model_id = model
        r.flops = [1.0, 4.0][model]
        r.admitted_t, r.started_t, r.finished_t = 0.0, t_done / 2, t_done
        reqs.append(r)
        m.on_arrival(r)
        m.on_admit(r)
        m.on_complete(r)
    m.on_batch(0, 2, 4)
    m.on_batch(1, 1, 4)
    m.on_model_busy(0, 0.5)
    m.on_stop(2.0)
    snap = m.snapshot()
    assert snap["arrived"] == snap["admitted"] == snap["completed"] == 3
    assert snap["slo_violations"] == 0
    assert snap["throughput_rps"] == pytest.approx(1.5)
    assert snap["called_fraction"] == [pytest.approx(2 / 3),
                                       pytest.approx(1 / 3)]
    assert snap["utilization"][0] == pytest.approx(0.25)
    assert snap["mean_batch_fill"] == pytest.approx(3 / 8)
    # Eq. 14: mean flops (1+1+4)/3 = 2 vs always-largest 4
    assert snap["mean_flops"] == pytest.approx(2.0)
    assert snap["flops_saved_frac"] == pytest.approx(0.5)
    assert snap["flops_saving_factor"] == pytest.approx(2.0)
    assert snap["total_p50_ms"] == pytest.approx(20.0)


def test_metrics_elapsed_accumulates_across_runs():
    m = SchedulerMetrics(costs=[1.0])
    m.on_start(0.0)
    m.on_stop(2.0)
    m.on_start(10.0)                       # restart
    snap = m.snapshot(now=11.0)            # mid second run
    # cumulative counters divide by cumulative serving time (2s + 1s),
    # not just the latest run's elapsed
    assert snap["elapsed_s"] == pytest.approx(3.0)
    m.on_stop(12.0)
    assert m.snapshot()["elapsed_s"] == pytest.approx(4.0)


def test_metrics_slo_violation_counted():
    m = SchedulerMetrics(costs=[1.0])
    r = _req(0, deadline_t=0.005)
    r.model_id, r.flops = 0, 1.0
    r.admitted_t, r.started_t, r.finished_t = 0.0, 0.001, 0.010
    m.on_complete(r)
    assert m.slo_violations == 1


# ---------------------------------------------------------------------------
# Traffic generation
# ---------------------------------------------------------------------------

def test_arrival_times_deterministic_and_rate():
    tc = TrafficConfig(rate=1000.0, num_requests=500, seed=3)
    t1, t2 = arrival_times(tc), arrival_times(tc)
    np.testing.assert_array_equal(t1, t2)
    assert np.all(np.diff(t1) >= 0)
    # mean rate within 20% of nominal for 500 samples
    assert t1[-1] == pytest.approx(0.5, rel=0.2)


def test_bursty_mean_rate_matches_nominal():
    tc = TrafficConfig(rate=1000.0, num_requests=20_000, pattern="bursty",
                       burst_factor=4.0, seed=1)
    t = arrival_times(tc)
    realized = len(t) / t[-1]
    assert realized == pytest.approx(1000.0, rel=0.15)


def test_latency_reservoir_is_bounded():
    from repro.serving.scheduler import LatencyReservoir
    r = LatencyReservoir(max_samples=64)
    for i in range(10_000):
        r.add(i / 1000.0)
    assert len(r) == 10_000              # observations counted
    assert len(r._samples) == 64         # memory bounded
    # a uniform sample of 0..10s should have a mid-range median
    assert 1_000.0 < r.percentile_ms(50) < 9_000.0


def test_bursty_arrivals_are_burstier_than_poisson():
    n = 2000
    pois = arrival_times(TrafficConfig(rate=1000.0, num_requests=n, seed=0))
    burst = arrival_times(TrafficConfig(rate=1000.0, num_requests=n,
                                        pattern="bursty", burst_factor=8.0,
                                        seed=0))
    cv = lambda t: np.std(np.diff(t)) / np.mean(np.diff(t))
    assert cv(burst) > cv(pois)          # CV of exp(λ) is 1; MMPP > 1
    with pytest.raises(ValueError):
        arrival_times(TrafficConfig(rate=1.0, num_requests=1,
                                    pattern="sawtooth"))


# ---------------------------------------------------------------------------
# End-to-end runtime (duck-typed server, no training needed)
# ---------------------------------------------------------------------------

class FakeServer:
    """Routes by the first feature's magnitude; model m scales by m+1."""

    def __init__(self, n=3):
        self.costs = np.asarray([1.0, 2.0, 4.0][:n], np.float32)
        self._n = n

    @property
    def num_models(self):
        return self._n

    def probe_weights(self, x):
        level = np.clip(np.abs(np.asarray(x)[:, 0]).astype(int), 0,
                        self._n - 1)
        w = np.zeros((len(level), self._n), np.float32)
        w[np.arange(len(level)), level] = 1.0
        return w

    def select(self, w):
        return np.argmax(np.asarray(w), axis=-1).astype(np.int32)

    def model_step(self, m, bucket):
        return np.asarray(bucket) * float(m + 1)


def test_scheduler_end_to_end_outputs_and_metrics():
    server = FakeServer()
    xs = [np.full(4, float(i % 3), np.float32)
          for i in range(24)]                           # routes 0,1,2,0,...

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=4,
                                                     max_wait_ms=2.0))
        async with sched:
            handles = [sched.submit(x) for x in xs]   # awaitable handles
            return sched, await asyncio.gather(*handles)

    sched, outs = asyncio.run(main())
    for i, (x, out) in enumerate(zip(xs, outs)):
        m = i % 3
        np.testing.assert_array_equal(out, x * (m + 1))
        np.testing.assert_array_equal(out, sched.reference_output(x, m))
    snap = sched.metrics.snapshot()
    assert snap["completed"] == 24
    assert snap["failed"] == 0
    assert snap["called_fraction"] == [pytest.approx(1 / 3)] * 3
    assert snap["mean_flops"] == pytest.approx((1 + 2 + 4) / 3)
    assert snap["batches"] >= 6          # >= ceil(8/4) buckets per model
    assert len(sched.queues[0]) == 0     # drained on stop


def test_submit_many_admits_batch_with_one_probe():
    class CountingServer(FakeServer):
        probe_calls = 0

        def probe_weights(self, x):
            CountingServer.probe_calls += 1
            return super().probe_weights(x)

    server = CountingServer()
    xs = [np.full(4, float(i % 3), np.float32) for i in range(6)]

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=4,
                                                     max_wait_ms=1.0,
                                                     probe_batch_size=8))
        async with sched:
            futures = sched.submit_many(xs)
            return await asyncio.gather(*futures)

    outs = asyncio.run(main())
    assert CountingServer.probe_calls == 1
    for i, out in enumerate(outs):
        np.testing.assert_array_equal(out, xs[i] * (i % 3 + 1))


def test_live_snapshot_reports_nonzero_rates():
    server = FakeServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=2,
                                                     max_wait_ms=1.0))
        async with sched:
            await sched.submit(np.zeros(4, np.float32))
            return sched.metrics.snapshot()     # mid-run: before stop()

    snap = asyncio.run(main())
    assert snap["completed"] == 1
    assert snap["elapsed_s"] > 0.0
    assert snap["throughput_rps"] > 0.0


def test_restarted_scheduler_snapshot_not_negative():
    server = FakeServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=2,
                                                     max_wait_ms=1.0))
        async with sched:
            await sched.submit(np.zeros(4, np.float32))
        async with sched:                       # restart the same instance
            await sched.submit(np.zeros(4, np.float32))
            snap = sched.metrics.snapshot()     # mid-run after restart
        return snap

    snap = asyncio.run(main())
    # a stale stopped_t from the first run would drive elapsed negative
    assert snap["elapsed_s"] > 0.0
    assert snap["throughput_rps"] >= 0.0
    assert all(u >= 0.0 for u in snap["utilization"])


def test_admission_probe_shape_is_fixed_across_burst_sizes():
    class ShapeRecordingServer(FakeServer):
        shapes = []

        def probe_weights(self, x):
            ShapeRecordingServer.shapes.append(np.asarray(x).shape)
            return super().probe_weights(x)

    server = ShapeRecordingServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=4,
                                                     max_wait_ms=1.0,
                                                     probe_batch_size=4))
        async with sched:
            futs = []
            for burst in (1, 2, 3, 5):   # 5 > probe batch: chunked
                futs += sched.submit_many(
                    [np.zeros(4, np.float32)] * burst)
            await asyncio.gather(*futs)

    asyncio.run(main())
    # every probe call padded to the fixed (probe_batch, ...) shape —
    # a novel shape would mean an XLA recompile on the event loop
    assert set(ShapeRecordingServer.shapes) == {(4, 4)}


def test_signature_mismatch_rejected_at_admission_not_batch():
    server = FakeServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=8,
                                                     max_wait_ms=1.0))
        async with sched:
            # the first successful admission sets the serving signature
            good_a = sched.submit(np.zeros(4, np.float32))
            # a mismatched request fails ITS OWN future at admission —
            # it must not reach the queue and poison good_a's bucket
            bad = sched.submit(np.zeros(7, np.float32))
            with pytest.raises(ValueError, match="serving signature"):
                await bad
            np.testing.assert_array_equal(await good_a, np.zeros(4))
            x = np.array([0.0, 5.0, 6.0, 7.0], np.float32)
            out = await sched.submit(x)
            np.testing.assert_array_equal(out, x)   # model 0 scales by 1
        snap = sched.metrics.snapshot()
        assert snap["completed"] == 2 and snap["failed"] == 1

    asyncio.run(main())


def test_admission_failure_resolves_futures_and_keeps_books_closed():
    class PickyServer(FakeServer):
        def probe_weights(self, x):
            if np.asarray(x).shape[-1] != 4:
                raise ValueError("bad feature width")
            return super().probe_weights(x)

    server = PickyServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=2,
                                                     max_wait_ms=1.0))
        async with sched:
            bad = sched.submit(np.zeros(9, np.float32))
            with pytest.raises(ValueError, match="bad feature width"):
                await bad
            out = await sched.submit(np.zeros(4, np.float32))
            np.testing.assert_array_equal(out, np.zeros(4))
        snap = sched.metrics.snapshot()
        # books closed: every arrival is either completed or failed
        assert snap["arrived"] == snap["completed"] + snap["failed"] == 2
        assert snap["failed"] == 1

    asyncio.run(main())


def test_scheduler_worker_failure_propagates():
    class BrokenServer(FakeServer):
        def model_step(self, m, bucket):
            raise RuntimeError("bucket exploded")

    async def main():
        sched = MuxScheduler(BrokenServer(),
                             SchedulerConfig(max_batch_size=2,
                                             max_wait_ms=1.0))
        async with sched:
            handle = sched.submit(np.zeros(4))
            with pytest.raises(RuntimeError, match="bucket exploded"):
                await handle
        assert sched.metrics.failed == 1

    asyncio.run(main())


def test_scheduler_stop_drains_partial_batches():
    server = FakeServer()

    async def main():
        # max_wait so long the only way out is the stop()-flush.
        # submit_nowait is the one-shot compat shim (handle.future) —
        # this test doubles as its pin.
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=64,
                                                     max_wait_ms=60_000.0))
        await sched.start()
        futures = [sched.submit_nowait(np.full(4, 1.0)) for _ in range(3)]
        await sched.stop(drain=True)
        outs = [f.result() for f in futures]
        for out in outs:
            np.testing.assert_array_equal(out, np.full(4, 2.0))
        assert sched.metrics.completed == 3
        with pytest.raises(RuntimeError, match="not running"):
            sched.submit_nowait(np.zeros(4))

    asyncio.run(main())


def test_lifecycle_double_start_raises_and_stop_is_idempotent():
    """SchedulerLifecycle contract (shared by MuxScheduler and
    PagedLLMScheduler): start() twice raises, stop() twice is a no-op,
    and a stopped scheduler rejects submissions."""
    server = FakeServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=2,
                                                     max_wait_ms=1.0))
        await sched.start()
        with pytest.raises(RuntimeError, match="already started"):
            await sched.start()
        await sched.submit(np.zeros(4, np.float32))
        await sched.stop()
        await sched.stop()                      # idempotent
        with pytest.raises(RuntimeError, match="not running"):
            sched.submit_nowait(np.zeros(4, np.float32))
        assert sched.metrics.completed == 1

    asyncio.run(main())


def test_lifecycle_drain_waits_for_all_inflight():
    server = FakeServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=4,
                                                     max_wait_ms=1.0))
        async with sched:
            handles = [sched.submit(np.zeros(4, np.float32))
                       for _ in range(6)]
            await sched.drain()
            assert all(h.done() for h in handles)
        assert sched.metrics.completed == 6

    asyncio.run(main())


def test_lifecycle_cancel_without_drain_fails_pending_futures():
    """A no-drain stop must not leave futures unresolved: queued work
    is cancelled with the workers."""
    class SlowServer(FakeServer):
        def model_step(self, m, bucket):
            import time as _t
            _t.sleep(0.05)
            return super().model_step(m, bucket)

    async def main():
        sched = MuxScheduler(SlowServer(),
                             SchedulerConfig(max_batch_size=64,
                                             max_wait_ms=60_000.0))
        await sched.start()
        handles = [sched.submit(np.zeros(4, np.float32))
                   for _ in range(3)]
        await sched.stop(drain=False)
        assert all(h.done() for h in handles)    # resolved or cancelled

    asyncio.run(main())


def test_open_loop_replay_respects_schedule():
    server = FakeServer()
    xs = [np.zeros(4) for _ in range(10)]

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=4,
                                                     max_wait_ms=1.0))
        async with sched:
            times = arrival_times(TrafficConfig(rate=500.0, num_requests=10,
                                                seed=0))
            futures = await replay(sched.submit, xs, times)
            await asyncio.gather(*futures)
        return sched.metrics.snapshot()

    snap = asyncio.run(main())
    assert snap["completed"] == 10
    assert snap["slo_violations"] == 0   # 100ms default SLO, light load
