"""Sharding rules / specs unit tests (no multi-device mesh needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config, list_architectures
from repro.launch import steps as steps_mod
from repro.models import transformer as tf
from repro.sharding import specs as sp
from repro.sharding.partition import (decode_rules, prefill_rules, resolve,
                                      train_rules)


class FakeMesh:
    axis_names = ("data", "model")

    class _Dev:
        shape = (16, 16)

    devices = _Dev()


def test_param_specs_cover_every_leaf():
    """Every 2D+ weight in every arch gets a spec with at least one
    sharded dim (except tiny norms/scalars)."""
    rules = train_rules(True, fsdp=True)
    for arch in list_architectures():
        cfg = get_smoke_config(arch)
        params = tf.abstract_params(cfg)
        spec_tree = sp.param_specs(params, rules)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
        assert len(flat) == len(specs)
        for (path, leaf), spec in zip(flat, specs):
            assert len(spec) <= leaf.ndim
            if leaf.ndim >= 2 and leaf.size > 1_000_000:
                assert any(a is not None for a in spec), \
                    f"{arch}: big leaf unsharded: {path}"


def test_full_config_divisibility_model_axis():
    """Sharded dims of every FULL config divide the 16-way model axis,
    except documented uneven cases handled by GSPMD padding:
    minicpm3's vocab (73448 = 8*9181) and llama4's 40 heads."""
    rules = resolve(train_rules(True), FakeMesh())
    known_uneven = {73448}                  # minicpm3 vocab, 8-divisible only
    for arch in list_architectures():
        cfg = get_config(arch)
        params = tf.abstract_params(cfg)
        spec_tree = sp.param_specs(params, rules)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat, specs):
            for dim, ax in zip(leaf.shape, spec):
                if ax == "model" and dim % 16 != 0:
                    assert dim in known_uneven, \
                        f"{arch} {sp._leaf_path(path)}: dim {dim} not 16-divisible"


def test_kv_shardable_logic():
    assert steps_mod.kv_shardable(get_config("codeqwen1.5-7b"))      # kv=32
    assert steps_mod.kv_shardable(get_config("gemma2-27b"))          # kv=16
    assert not steps_mod.kv_shardable(get_config("jamba-v0.1-52b"))  # kv=8
    assert not steps_mod.kv_shardable(get_config("minicpm3-4b"))     # MLA
    assert steps_mod.kv_shardable(get_config("falcon-mamba-7b"))     # no attn


def test_rules_no_duplicate_axes_possible():
    """cache_seq and kv_heads never map to the same mesh axis."""
    for kvs in (True, False):
        for bs in (True, False):
            r = decode_rules(kvs, bs)
            cs, kh = r["cache_seq"], r["kv_heads"]
            cs_axes = set(cs if isinstance(cs, tuple) else [cs]) - {None}
            kh_axes = set(kh if isinstance(kh, tuple) else [kh]) - {None}
            assert not (cs_axes & kh_axes)


def test_resolve_drops_missing_axes():
    r = resolve(train_rules(True), FakeMesh())
    assert r["batch"] == ("data",)          # 'pod' dropped on single pod


def test_sharded_bytes_math():
    tree = {"a": jax.ShapeDtypeStruct((32, 64), jnp.float32)}
    spec = {"a": P("data", "model")}
    got = sp.sharded_bytes(tree, spec, FakeMesh())
    assert got == 32 * 64 * 4 // 256
    spec2 = {"a": P(None, ("data", "model"))}
    assert sp.sharded_bytes(tree, spec2, FakeMesh()) == 32 * 64 * 4 // 256
    spec3 = {"a": P()}
    assert sp.sharded_bytes(tree, spec3, FakeMesh()) == 32 * 64 * 4


def test_cache_specs_shape_alignment():
    cfg = get_smoke_config("jamba-v0.1-52b")
    caches = tf.abstract_caches(cfg, 4, 64)
    rules = decode_rules(False, True)
    spec_tree = sp.cache_specs(caches, rules)
    flat = jax.tree_util.tree_flatten_with_path(caches)[0]
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, specs):
        assert len(spec) <= leaf.ndim, (path, leaf.shape, spec)
