"""Speculative decoding is token-exact by construction: the verifier's
multi-token step recomputes exactly what plain greedy decode would
have, so accepted-or-not, the committed stream is bitwise the plain
stream.  This matrix drives SpeculativeBackend across attention
variants x KV-cache dtypes x backend topologies and asserts output
identity against target-only ``generate_paged``, with both drafter
regimes covered: a same-params drafter (acceptance is structural) and
an independent drafter (most drafts reject, exercising rollback).
Also: the acceptance-EMA fallback to plain decode, and the k=0
degenerate path for mux-probed hard inputs."""
import asyncio

import jax
import numpy as np
import pytest

from test_paged_decode import tiny_config

from repro.models import transformer as tf
from repro.serving.backend import (DisaggregatedBackend, InProcessBackend,
                                   RemoteStubBackend)
from repro.serving.engine import Engine, ServeConfig
from repro.serving.spec_decode import SpeculativeBackend

MAX_LEN = 48
MAX_NEW = 10
DRAFT_K = 3

# Curated so every attention variant, KV dtype, backend topology, and
# drafter regime appears at least twice without running the full
# 5x2x3x2 cross product (compile time, not coverage, is the binding
# constraint — the verify kernel under test is shared by all cells).
MATRIX = [
    ("full",      "bfloat16", "inproc", "same"),
    ("swa",       "int8",     "inproc", "diverse"),
    ("chunked",   "bfloat16", "disagg", "same"),
    ("gqa_mixed", "int8",     "remote", "diverse"),
    ("mla",       "bfloat16", "inproc", "same"),
    ("full",      "int8",     "disagg", "diverse"),
    ("swa",       "bfloat16", "remote", "same"),
]


def build_engine(cfg, params, *, lazy=False, max_len=MAX_LEN, pages=60):
    eng = Engine(cfg, params, ServeConfig(max_len=max_len))
    eng.init_paged(num_pages=pages, page_size=4, decode_batch=4,
                   span_reclaim=not lazy, lazy_decode_alloc=lazy)
    return eng


def make_spec(cfg, params, dparams, backend_kind, **spec_kw):
    """Returns (driveable backend, SpeculativeBackend for stats)."""
    draft = build_engine(cfg, dparams, lazy=True, max_len=MAX_LEN + 16,
                         pages=80)
    spec_kw.setdefault("draft_k", DRAFT_K)
    if backend_kind == "disagg":
        target = DisaggregatedBackend.build(
            cfg, params, ServeConfig(max_len=MAX_LEN), num_pages=60,
            page_size=4, decode_batch=4)
    else:
        target = InProcessBackend(build_engine(cfg, params))
    spec = SpeculativeBackend(target, draft, **spec_kw)
    if backend_kind == "remote":
        return RemoteStubBackend(spec), spec
    return spec, spec


def prompts_for(cfg):
    return [np.asarray(jax.random.randint(jax.random.key(i), (7 + i,), 0,
                                          cfg.vocab_size))
            for i in range(3)]


def plain_refs(cfg, params, prompts):
    eng = build_engine(cfg, params)
    return [list(eng.generate_paged(p, max_new_tokens=MAX_NEW)["tokens"]
                 [len(p):]) for p in prompts]


async def drive(backend, prompts, max_new=MAX_NEW):
    await backend.start()
    outs = []
    try:
        seqs = []
        for p in prompts:
            seq = backend.begin(p, max_new_tokens=max_new)
            while not await backend.prefill_chunk(seq):
                pass
            seqs.append(seq)
        live = list(seqs)
        while live:
            await backend.decode_batch(live)
            live = [s for s in live if not s.done]
        for s in seqs:
            outs.append(list(s.tokens))
            backend.release(s)
    finally:
        await backend.stop()
    return outs


def assert_drained(spec: SpeculativeBackend):
    stats = spec.stats()
    assert stats["draft_pool"]["pages_in_use"] == 0, stats
    assert stats["pool"]["pages_in_use"] == 0, stats
    if "prefill_pool" in stats:
        assert stats["prefill_pool"]["pages_in_use"] == 0, stats


@pytest.mark.parametrize(
    "variant,kv_dtype,backend_kind,drafter", MATRIX,
    ids=[f"{v}-{d}-{b}-{dr}" for v, d, b, dr in MATRIX])
def test_spec_decode_parity(variant, kv_dtype, backend_kind, drafter):
    cfg = tiny_config(variant, kv_cache_dtype=kv_dtype)
    params = tf.init_params(cfg, jax.random.key(0))
    dparams = (params if drafter == "same"
               else tf.init_params(cfg, jax.random.key(7)))
    prompts = prompts_for(cfg)
    refs = plain_refs(cfg, params, prompts)

    backend, spec = make_spec(cfg, params, dparams, backend_kind)
    outs = asyncio.run(drive(backend, prompts))
    assert outs == refs                      # bitwise the plain stream

    stats = spec.stats()
    assert stats["draft_tokens"] > 0
    if drafter == "same":
        # structural acceptance: the drafter IS the verifier
        assert stats["accepted_tokens"] == stats["draft_tokens"]
    assert_drained(spec)


def test_acceptance_ema_fallback():
    """An independent drafter whose tokens keep rejecting must trip the
    acceptance-rate EMA floor and collapse to plain decode — releasing
    the draft cache — while the output stream stays exact."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    dparams = tf.init_params(cfg, jax.random.key(9))
    prompts = prompts_for(cfg)
    refs = plain_refs(cfg, params, prompts)

    backend, spec = make_spec(cfg, params, dparams, "inproc",
                              ema_alpha=0.9, ema_floor=0.9)
    outs = asyncio.run(drive(backend, prompts))
    assert outs == refs

    stats = spec.stats()
    assert stats["spec_fallbacks"] == len(prompts)
    assert stats["accepted_tokens"] < stats["draft_tokens"]
    assert_drained(spec)


def test_k0_degenerate_plain_decode():
    """Mux-probed hard inputs (k=0) never draft: the request runs plain
    target decode from the first sweep, with no draft pages ever held."""
    cfg = tiny_config("full")
    params = tf.init_params(cfg, jax.random.key(0))
    prompts = prompts_for(cfg)
    refs = plain_refs(cfg, params, prompts)

    backend, spec = make_spec(cfg, params, params, "inproc",
                              k_fn=lambda prompt: 0)
    outs = asyncio.run(drive(backend, prompts))
    assert outs == refs

    stats = spec.stats()
    assert stats["draft_tokens"] == 0
    assert stats["verify_rounds"] == 0
    # probe-routed plain decode is a routing decision, not a dynamic
    # collapse — the spec_fallbacks counter only tracks the latter
    assert stats["spec_fallbacks"] == 0
    assert stats["draft_pool"]["peak_pages_in_use"] == 0
    assert_drained(spec)
