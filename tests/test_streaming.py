"""The streaming generation API: SamplingParams/GenerationHandle
surface, token events, chunked prefill (interleave + parity),
cancellation at every phase (queue-wait / mid-chunked-prefill /
mid-decode) with zero page leaks, idempotent terminal transitions,
the deadline-degrade admission hook, and the cross-request logit
cache."""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import transformer as tf
from repro.serving.engine import Engine, ServeConfig
from repro.serving.scheduler import (EventType, MuxScheduler, PagedLLMConfig,
                                     PagedLLMScheduler, Request, RequestState,
                                     SamplingParams, SchedulerConfig)
from repro.serving.scheduler.batcher import ModelQueue

PS = 4          # page size everywhere here


def tiny_config() -> ModelConfig:
    return ModelConfig(name="stream-tiny", arch_type="dense", num_layers=2,
                       d_model=32, d_ff=64, vocab_size=64, num_heads=4,
                       num_kv_heads=2, head_dim=8, compute_dtype="float32",
                       param_dtype="float32", kv_cache_dtype="float32")


@pytest.fixture(scope="module")
def model():
    cfg = tiny_config()
    return cfg, tf.init_params(cfg, jax.random.key(0))


def make_engine(model, num_pages=40, decode_batch=4, **kw) -> Engine:
    cfg, params = model
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    eng.init_paged(num_pages=num_pages, page_size=PS,
                   decode_batch=decode_batch, **kw)
    return eng


def prompt_of(n, fold=0, model=None):
    cfg = model[0] if model else tiny_config()
    return np.asarray(jax.random.randint(jax.random.fold_in(
        jax.random.key(5), fold), (n,), 0, cfg.vocab_size))


# ---------------------------------------------------------------------------
# Idempotent terminal transitions (regression: cancel racing completion)
# ---------------------------------------------------------------------------

def test_terminal_transitions_first_one_wins():
    """complete/fail/cancel are idempotent: the first transition wins,
    every later call is a no-op returning False — a cancel racing a
    worker completion can no longer depend on worker timing."""
    req = Request(rid=0, x=np.zeros(2), arrival_t=0.0, deadline_t=1.0)
    assert req.complete("out", 0.5)
    assert req.state is RequestState.COMPLETED
    assert not req.fail(RuntimeError("late"), 0.6)      # loses the race
    assert not req.cancel(0.7)
    assert not req.complete("other", 0.8)
    assert req.state is RequestState.COMPLETED
    assert req.output == "out" and req.finished_t == 0.5

    req2 = Request(rid=1, x=np.zeros(2), arrival_t=0.0, deadline_t=1.0)
    assert req2.cancel(0.3)
    assert not req2.complete("out", 0.4)                # completion loses
    assert req2.state is RequestState.CANCELLED
    assert req2.finish_reason == "cancelled"

    req3 = Request(rid=2, x=np.zeros(2), arrival_t=0.0, deadline_t=1.0)
    assert req3.fail(ValueError("boom"), 0.2)
    assert not req3.fail(ValueError("again"), 0.3)      # counted once
    assert req3.finished_t == 0.2


def test_cancel_racing_completion_resolves_future_once():
    async def main():
        loop = asyncio.get_running_loop()
        req = Request(rid=0, x=np.zeros(2), arrival_t=0.0, deadline_t=1.0,
                      future=loop.create_future())
        assert req.complete("out", 0.5)
        assert not req.cancel(0.6)          # future already resolved
        assert await req.future == "out"    # not CancelledError

        req2 = Request(rid=1, x=np.zeros(2), arrival_t=0.0, deadline_t=1.0,
                       future=loop.create_future())
        assert req2.cancel(0.5)
        assert not req2.complete("out", 0.6)
        with pytest.raises(asyncio.CancelledError):
            await req2.future

    asyncio.run(main())


def test_sampling_params_priority_orders_queue():
    q = ModelQueue(0)
    lo = Request(rid=0, x=None, arrival_t=0.0, deadline_t=1.0)
    hi = Request(rid=1, x=None, arrival_t=0.0, deadline_t=5.0,
                 params=SamplingParams(priority=3))
    q.push(lo, now=0.0)
    q.push(hi, now=0.0)
    # priority outranks the (much earlier) deadline of the low request
    assert q.pop() is hi and q.pop() is lo


# ---------------------------------------------------------------------------
# Streaming events on the paged path
# ---------------------------------------------------------------------------

def test_streaming_events_match_result(model):
    """Event order is PREFILLING* FIRST_TOKEN TOKEN* FINISHED with
    monotone timestamps, the streamed tokens equal the result() tail,
    and TTFT/ITL land in the metrics snapshot."""
    eng = make_engine(model)
    prompt = prompt_of(9, model=model)
    ref = eng.generate_paged(prompt, max_new_tokens=6)["tokens"]

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig())
        async with sched:
            handle = sched.submit(
                prompt, SamplingParams(max_new_tokens=6, stream=True))
            evs = [ev async for ev in handle]
            out = await handle.result()
        return sched, out, evs

    sched, out, evs = asyncio.run(main())
    np.testing.assert_array_equal(out, ref)
    types = [e.type for e in evs]
    assert types[0] is EventType.PREFILLING
    assert types[-1] is EventType.FINISHED
    first = types.index(EventType.FIRST_TOKEN)
    assert all(t is EventType.PREFILLING for t in types[:first])
    assert all(t is EventType.TOKEN for t in types[first + 1:-1])
    ts = [e.t for e in evs]
    assert ts == sorted(ts)
    streamed = [e.token for e in evs
                if e.type in (EventType.FIRST_TOKEN, EventType.TOKEN)]
    np.testing.assert_array_equal(streamed, out[len(prompt):])
    assert evs[-1].finish_reason == "length"
    np.testing.assert_array_equal(evs[-1].output, out)
    snap = sched.snapshot()
    assert snap["ttft_p50_ms"] > 0.0
    assert snap["itl_p50_ms"] > 0.0
    assert snap["pools"][0]["pages_in_use"] == 0


def test_non_streaming_handle_rejects_iteration(model):
    eng = make_engine(model)

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=2))
        async with sched:
            handle = sched.submit(prompt_of(5, model=model))
            with pytest.raises(RuntimeError, match="stream"):
                async for _ in handle:
                    pass
            return await handle        # handles are awaitable

    out = asyncio.run(main())
    assert len(out) == 7


def test_stop_tokens_end_generation_early(model):
    """A sampled stop token terminates the stream with reason "stop"
    and the result is trimmed at the stop token."""
    eng = make_engine(model)
    prompt = prompt_of(7, model=model)
    ref = eng.generate_paged(prompt, max_new_tokens=10)["tokens"]
    stop = int(ref[len(prompt) + 2])     # the 3rd generated token

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig())
        async with sched:
            handle = sched.submit(prompt, SamplingParams(
                max_new_tokens=10, stop_tokens=(stop,), stream=True))
            evs = [ev async for ev in handle]
            out = await handle
        return out, evs

    out, evs = asyncio.run(main())
    np.testing.assert_array_equal(out, ref[:len(prompt) + 3])
    assert evs[-1].finish_reason == "stop"


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_engine_parity(model):
    """The resumable chunk path produces token-identical output to the
    serial whole-prompt prefill, including over a resident shared
    prefix, and a mid-prefill release is a complete rollback."""
    cfg, params = model
    ref_eng = make_engine(model)
    pa = prompt_of(11, fold=1, model=model)
    pb = np.concatenate([pa[:8], prompt_of(9, fold=2, model=model)])
    ref_a = ref_eng.generate_paged(pa, max_new_tokens=5)["tokens"]
    ref_b = ref_eng.generate_paged(pb, max_new_tokens=5)["tokens"]

    eng = make_engine(model)
    sa = eng.begin_prefill(pa, max_new_tokens=5)
    chunks = 0
    while not eng.prefill_chunk(sa, chunk_tokens=PS):
        chunks += 1
    assert chunks >= 2                       # 11 tokens / 4-token chunks
    sb = eng.begin_prefill(pb, max_new_tokens=5)
    eng.prefill_chunk(sb, chunk_tokens=PS)   # first chunk maps lazily
    assert sb.shared_prefix_len == 8         # maps sa's resident prefix
    while not eng.prefill_chunk(sb, chunk_tokens=PS):
        pass
    while not (sa.done and sb.done):
        eng.decode_step_batch([s for s in (sa, sb) if not s.done])
    np.testing.assert_array_equal(np.concatenate([pa, sa.tokens]), ref_a)
    np.testing.assert_array_equal(np.concatenate([pb, sb.tokens]), ref_b)
    eng.pool.release(sa)
    eng.pool.release(sb)
    assert eng.pool.pages_in_use == 0

    # mid-prefill rollback: pages allocated so far all hand back
    sc = eng.begin_prefill(prompt_of(16, fold=3, model=model),
                           max_new_tokens=4)
    eng.prefill_chunk(sc, chunk_tokens=2 * PS)
    assert not sc.prefill_done and eng.pool.pages_in_use > 0
    eng.pool.release(sc)
    assert eng.pool.pages_in_use == 0


def test_chunked_prefill_interleaves_with_decode(model):
    """A long prompt admitted behind a running stream must not stall
    it: with prefill_chunk_pages set, the running request keeps
    emitting TOKEN events *between* the long prompt's PREFILLING
    events, and both outputs equal their serial references."""
    eng = make_engine(model)
    long_p = prompt_of(40, model=model)
    short_p = prompt_of(6, fold=1, model=model)
    ref_long = eng.generate_paged(long_p, max_new_tokens=6)["tokens"]
    ref_short = eng.generate_paged(short_p, max_new_tokens=12)["tokens"]

    async def main():
        sched = PagedLLMScheduler(
            [eng], PagedLLMConfig(prefill_chunk_pages=2))
        sched.warmup([6, 40])
        async with sched:
            hs = sched.submit(short_p, SamplingParams(max_new_tokens=12,
                                                      stream=True))
            while sched.decode_batches < 1:      # short is mid-generation
                await asyncio.sleep(0.002)
            hl = sched.submit(long_p, SamplingParams(max_new_tokens=6,
                                                     stream=True))
            evs_l = [ev async for ev in hl]
            out_l = await hl
            out_s = await hs
            evs_s = [ev async for ev in hs]
        return sched, out_s, out_l, evs_s, evs_l

    sched, out_s, out_l, evs_s, evs_l = asyncio.run(main())
    np.testing.assert_array_equal(out_l, ref_long)
    np.testing.assert_array_equal(out_s, ref_short)
    # 40 tokens at 8-token chunks: >= 4 prefill-progress events
    assert sum(e.type is EventType.PREFILLING for e in evs_l) >= 4
    lp0 = min(e.t for e in evs_l if e.type is EventType.PREFILLING)
    lft = next(e.t for e in evs_l if e.type is EventType.FIRST_TOKEN)
    interleaved = [e for e in evs_s
                   if e.type is EventType.TOKEN and lp0 < e.t < lft]
    assert interleaved, "no short-stream token landed during long prefill"
    snap = sched.snapshot()
    assert snap["prefill_chunks"] >= 5
    assert snap["interleaved_chunks"] >= 1
    assert snap["pools"][0]["pages_in_use"] == 0


def test_chunked_admission_budgets_first_chunk(model):
    """With chunked prefill, a prompt whose WHOLE page span exceeds the
    current free pages still admits on its first chunk and completes as
    running requests retire (serial admission would hold it back)."""
    eng = make_engine(model, num_pages=12, decode_batch=2)  # 11 usable pages
    long_p = prompt_of(28, model=model)      # 28+4 tokens -> 8 pages
    short_p = prompt_of(8, fold=1, model=model)  # 8+4 -> 3 pages
    ref_long = eng.generate_paged(long_p, max_new_tokens=4)["tokens"]
    ref_short = eng.generate_paged(short_p, max_new_tokens=4)["tokens"]

    async def main():
        sched = PagedLLMScheduler(
            [eng], PagedLLMConfig(max_new_tokens=4, prefill_chunk_pages=1))
        async with sched:
            h1 = sched.submit(short_p)
            h2 = sched.submit(long_p)
            return await asyncio.gather(h1, h2)

    out_s, out_l = asyncio.run(main())
    np.testing.assert_array_equal(out_s, ref_short)
    np.testing.assert_array_equal(out_l, ref_long)
    assert eng.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# Cancellation at every phase
# ---------------------------------------------------------------------------

async def _pool_drains(pool, target=0, tries=400):
    for _ in range(tries):
        if pool.pages_in_use == target:
            return True
        await asyncio.sleep(0.005)
    return False


def test_cancel_every_phase_restores_pool(model):
    """Cancel during queue-wait, mid-chunked-prefill, and mid-decode:
    each resolves the future with CancelledError and returns the pool
    to its pre-admission unique-page count."""
    eng = make_engine(model, decode_batch=2)
    long_p = prompt_of(40, model=model)
    short_p = prompt_of(6, fold=1, model=model)

    async def main():
        sched = PagedLLMScheduler(
            [eng], PagedLLMConfig(max_new_tokens=24, prefill_chunk_pages=1))
        async with sched:
            # ---- mid-decode ----
            h = sched.submit(short_p, stream=True)
            async for ev in h:
                if ev.type is EventType.TOKEN:
                    break
            assert h.cancel()
            assert not h.cancel()                # second cancel is a no-op
            with pytest.raises(asyncio.CancelledError):
                await h
            assert await _pool_drains(eng.pool)

            # ---- mid-chunked-prefill ----
            h = sched.submit(long_p, max_new_tokens=6, stream=True)
            async for ev in h:
                if ev.type is EventType.PREFILLING and ev.prefilled:
                    break
            assert h.cancel()
            with pytest.raises(asyncio.CancelledError):
                await h
            assert await _pool_drains(eng.pool)

            # ---- queue-wait: both decode slots busy, third queues ----
            running = [sched.submit(short_p, max_new_tokens=24)
                       for _ in range(2)]
            queued = sched.submit(short_p, max_new_tokens=4)
            assert queued.cancel()
            with pytest.raises(asyncio.CancelledError):
                await queued
            outs = await asyncio.gather(*running)
            assert all(len(o) == 30 for o in outs)
        return sched

    sched = asyncio.run(main())
    assert eng.pool.pages_in_use == 0
    snap = sched.snapshot()
    assert snap["cancelled"] == 3 and snap["failed"] == 0
    assert snap["arrived"] == (snap["completed"] + snap["failed"]
                               + snap["cancelled"])


def test_join_drops_request_cancelled_during_final_chunk(model):
    """A request cancelled while its final prefill chunk is on the
    executor must not be resurrected by _join: the sequence's pages
    release and it never enters the decode roster (regression for the
    cancel-vs-join race)."""
    eng = make_engine(model)
    sched = PagedLLMScheduler([eng], PagedLLMConfig())
    seq = eng.prefill_into_pages(prompt_of(6, model=model), max_new_tokens=4)
    req = Request(rid=0, x=prompt_of(6, model=model), arrival_t=0.0,
                  deadline_t=1.0)
    assert req.cancel(0.5)
    sched._join(0, req, seq, 0)
    assert len(sched.slots[0]) == 0          # never joined
    assert req.state is RequestState.CANCELLED   # not resurrected
    assert eng.pool.pages_in_use == 0        # pages released


# ---------------------------------------------------------------------------
# Cross-request logit cache
# ---------------------------------------------------------------------------

def test_logit_cache_zero_flop_repeat_admission(model):
    """A fully-resident repeat prompt with a cached final-token logits
    row skips prefill entirely (zero tokens computed), still COWs its
    boundary page on decode, and generates the reference tokens."""
    ref_eng = make_engine(model)
    prompt = prompt_of(10, model=model)      # 10 % 4 != 0: boundary page
    ref = ref_eng.generate_paged(prompt, max_new_tokens=5)["tokens"]

    eng = make_engine(model, logit_cache=4)
    a = eng.prefill_into_pages(prompt, max_new_tokens=5)
    computed = eng.prefill_tokens_computed
    b = eng.prefill_into_pages(prompt, max_new_tokens=5)
    assert eng.logit_cache_hits == 1
    assert eng.prefill_tokens_computed == computed   # zero-FLOP admission
    assert b.prefill_done and b.shared_prefix_len == len(prompt)
    while not (a.done and b.done):
        eng.decode_step_batch([s for s in (a, b) if not s.done])
    np.testing.assert_array_equal(np.concatenate([prompt, a.tokens]), ref)
    np.testing.assert_array_equal(np.concatenate([prompt, b.tokens]), ref)
    assert eng.cow_count == 1                # boundary page still COWed
    eng.pool.release(a)
    eng.pool.release(b)
    assert eng.pool.pages_in_use == 0

    # LRU bound: capacity 4 holds at most 4 entries
    for i in range(6):
        s = eng.prefill_into_pages(prompt_of(6, fold=10 + i, model=model),
                                   max_new_tokens=2)
        eng.pool.release(s)
    assert len(eng._logit_cache) <= 4


def test_logit_cache_counters_in_snapshot(model):
    eng = make_engine(model, logit_cache=8)
    prompt = prompt_of(8, model=model)

    async def main():
        sched = PagedLLMScheduler([eng], PagedLLMConfig(max_new_tokens=3))
        async with sched:
            a = sched.submit(prompt)
            b = sched.submit(prompt)
            await asyncio.gather(a, b)
        return sched.snapshot()

    snap = asyncio.run(main())
    assert snap["logit_cache_hits"] + snap["logit_cache_misses"] >= 1
    assert snap["pools"][0]["pages_in_use"] == 0


# ---------------------------------------------------------------------------
# Mux path: unified handle surface + deadline degrade
# ---------------------------------------------------------------------------

class FakeServer:
    """Routes by the first feature's magnitude; model m scales by m+1."""

    def __init__(self, n=3):
        self.costs = np.asarray([1.0, 2.0, 4.0][:n], np.float32)
        self._n = n

    @property
    def num_models(self):
        return self._n

    def probe_weights(self, x):
        level = np.clip(np.abs(np.asarray(x)[:, 0]).astype(int), 0,
                        self._n - 1)
        w = np.zeros((len(level), self._n), np.float32)
        w[np.arange(len(level)), level] = 1.0
        return w

    def select(self, w):
        return np.argmax(np.asarray(w), axis=-1).astype(np.int32)

    def model_step(self, m, bucket):
        return np.asarray(bucket) * float(m + 1)


def test_mux_submit_returns_streaming_handle():
    server = FakeServer()

    async def main():
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=2,
                                                     max_wait_ms=1.0))
        async with sched:
            h = sched.submit(np.zeros(4, np.float32),
                             SamplingParams(stream=True))
            evs = [ev async for ev in h]
            out = await h.result()
        return sched, out, evs

    sched, out, evs = asyncio.run(main())
    np.testing.assert_array_equal(out, np.zeros(4))
    assert [e.type for e in evs] == [EventType.FINISHED]
    assert sched.metrics.snapshot()["ttft_p50_ms"] > 0.0


def test_mux_cancel_in_queue_skips_bucket():
    server = FakeServer()

    async def main():
        # max_wait so long only the stop-flush drains the queue
        sched = MuxScheduler(server, SchedulerConfig(max_batch_size=64,
                                                     max_wait_ms=60_000.0))
        await sched.start()
        keep = sched.submit(np.full(4, 1.0, np.float32))
        dropped = sched.submit(np.full(4, 1.0, np.float32))
        assert dropped.cancel()
        with pytest.raises(asyncio.CancelledError):
            await dropped
        await sched.stop(drain=True)
        np.testing.assert_array_equal(keep.future.result(), np.full(4, 2.0))
        return sched

    sched = asyncio.run(main())
    snap = sched.metrics.snapshot()
    assert snap["completed"] == 1 and snap["cancelled"] == 1
    assert snap["arrived"] == (snap["completed"] + snap["failed"]
                               + snap["cancelled"])


def test_no_drain_stop_emits_finished_for_streams():
    """stop(drain=False) must fail stranded requests THROUGH the
    request (emitting FINISHED) so a streaming consumer is unblocked
    rather than hanging on an abandoned event queue forever."""
    class SlowServer(FakeServer):
        def model_step(self, m, bucket):
            import time as _t
            _t.sleep(0.05)
            return super().model_step(m, bucket)

    async def main():
        sched = MuxScheduler(SlowServer(),
                             SchedulerConfig(max_batch_size=64,
                                             max_wait_ms=60_000.0))
        await sched.start()
        h = sched.submit(np.zeros(4, np.float32), SamplingParams(stream=True))

        async def consume():
            return [ev async for ev in h]

        task = asyncio.create_task(consume())
        await asyncio.sleep(0)               # let the consumer block
        await sched.stop(drain=False)
        evs = await asyncio.wait_for(task, timeout=5.0)   # must not hang
        assert evs[-1].type is EventType.FINISHED
        # the flush may legitimately win the race and complete the
        # request; either way the stream terminates with FINISHED
        if evs[-1].finish_reason == "error":
            with pytest.raises(RuntimeError, match="stopped before"):
                await h
        else:
            assert evs[-1].finish_reason == "complete"
            np.testing.assert_array_equal(await h, np.zeros(4))
        return sched

    sched = asyncio.run(main())
    snap = sched.metrics.snapshot()
    assert snap["arrived"] == (snap["completed"] + snap["failed"]
                               + snap["cancelled"])


def test_deadline_degrade_reroutes_to_cheapest():
    """MDInference hook: when the selected model's estimated service
    time exceeds the request's SLO budget, admission re-routes to the
    cheapest model whose estimate fits.  Off by default."""
    server = FakeServer()
    x_heavy = np.full(4, 2.0, np.float32)     # probe routes to model 2

    async def run(degrade):
        sched = MuxScheduler(server, SchedulerConfig(
            max_batch_size=2, max_wait_ms=1.0, deadline_degrade=degrade))
        # prime the estimator: model 2 is far too slow for a 50ms SLO,
        # models 0/1 easily fit
        sched.metrics._service_ema = [0.001, 0.002, 10.0]
        async with sched:
            out = await sched.submit(x_heavy, slo_ms=50.0)
        return sched, np.asarray(out)

    sched_off, out_off = asyncio.run(run(False))
    np.testing.assert_array_equal(out_off, x_heavy * 3)   # model 2
    assert sched_off.metrics.deadline_degraded == 0

    sched_on, out_on = asyncio.run(run(True))
    np.testing.assert_array_equal(out_on, x_heavy * 1)    # cheapest fitting
    snap = sched_on.metrics.snapshot()
    assert snap["deadline_degraded"] == 1
    assert snap["completed"] == 1
