"""Optimizer, checkpoint, data pipeline, trainer, serving engine tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_smoke_config
from repro.data.pipeline import ShardedBatcher, host_slice
from repro.data.synthetic import (image_dataset, lm_batch, make_templates,
                                  sample_images)
from repro.optim import adamw

KEY = jax.random.key(1)


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200, schedule="constant",
                            clip_norm=None)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(cfg, params)
    target = jnp.array([1.0, 1.0])
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw.apply_updates(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clipping_caps_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=1,
                            total_steps=10, schedule="constant",
                            weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init(cfg, params)
    grads = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, _, m = adamw.apply_updates(cfg, params, grads, state)
    assert float(m["grad_norm"]) > 1e5      # reported pre-clip norm


def test_adamw_bf16_moments():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    state = adamw.init(cfg, {"w": jnp.zeros((4, 4))})
    assert state.mu["w"].dtype == jnp.bfloat16


def test_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lr0 = adamw.schedule_lr(cfg, jnp.asarray(0))
    lr10 = adamw.schedule_lr(cfg, jnp.asarray(10))
    lr99 = adamw.schedule_lr(cfg, jnp.asarray(99))
    assert float(lr0) < float(lr10)
    assert float(lr99) < float(lr10)


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": [jnp.zeros(2), jnp.ones(3)]}
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, tree, step=7)
    like = jax.eval_shape(lambda: tree)
    back = ckpt.restore(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ckpt.latest_step(str(tmp_path)) is None


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, {"a": jnp.zeros((2, 2))})
    with pytest.raises(AssertionError):
        ckpt.restore(path, {"a": jnp.zeros((3, 2))})


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

def test_hardness_controls_difficulty():
    """Higher-hardness samples are farther from their class template."""
    templates = make_templates(KEY, num_classes=4, image_size=16)
    easy = sample_images(KEY, templates, batch=128,
                         hardness=jnp.zeros(128))
    hard = sample_images(KEY, templates, batch=128,
                         hardness=jnp.full((128,), 0.9))
    d_easy = jnp.abs(easy["image"] - templates[easy["label"]]).mean()
    d_hard = jnp.abs(hard["image"] - templates[hard["label"]]).mean()
    assert float(d_hard) > float(d_easy) * 1.5


def test_label_corruption_tail():
    templates = make_templates(KEY, num_classes=4, image_size=8)
    out = sample_images(KEY, templates, batch=64,
                        hardness=jnp.ones(64) * 0.99)
    clean = sample_images(KEY, templates, batch=64,
                          hardness=jnp.zeros(64))
    assert out["image"].shape == clean["image"].shape


def test_lm_batch_structured_and_deterministic():
    b1 = lm_batch(KEY, batch=4, seq_len=32, vocab_size=50)
    b2 = lm_batch(KEY, batch=4, seq_len=32, vocab_size=50)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


def test_host_slicing_partitions_batch():
    slices = [host_slice(64, i, 4) for i in range(4)]
    seen = set()
    for s in slices:
        seen.update(range(s.start, s.stop))
    assert seen == set(range(64))


def test_sharded_batcher_local_slice():
    def fn(key, b):
        return {"x": jnp.arange(b)}
    it = iter(ShardedBatcher(fn, global_batch=16, process_index=1,
                             process_count=4))
    batch = next(it)
    np.testing.assert_array_equal(np.asarray(batch["x"]), np.arange(4, 8))


# --------------------------------------------------------------------------
# trainer + serving engine (smoke-scale end to end)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_trainer_loss_decreases():
    from repro.training.trainer import Trainer, TrainerConfig
    cfg = get_smoke_config("olmo-1b").with_(vocab_size=16)
    tcfg = TrainerConfig(steps=60, batch_size=8, seq_len=64, log_every=5)
    opt = adamw.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                            schedule="constant")
    out = Trainer(cfg, tcfg, opt).run(verbose=False)
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
def test_engine_generate():
    from repro.serving.engine import Engine, ServeConfig
    cfg = get_smoke_config("olmo-1b")
    from repro.models import transformer as tf
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                          tf.init_params(cfg, KEY))
    eng = Engine(cfg, params, ServeConfig(max_len=48))
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    res = eng.generate(prompts, max_new_tokens=8)
    assert res["tokens"].shape == (2, 16)
    assert res["tokens_per_s"] > 0
