"""End-to-end behaviour of the paper's system (Alg. 1 + Alg. 2).

Mini-scale: trains the 6-CNN zoo with the contrastive loss, trains the
multiplexer, and checks the qualitative claims the paper makes:
  * the mux routes easy inputs to cheap models (FLOPs saving vs
    always-largest),
  * hybrid accuracy >= best single model on the routed mix,
  * the contrastive loss increases push/pull separation,
  * the MuxServer serves the multiplexed batch end to end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_mux import smoke_config
from repro.core import contrastive as cnt
from repro.core import ensemble as ens
from repro.core import mux_train
from repro.core.multiplexer import mux_forward
from repro.data.synthetic import image_dataset, make_templates
from repro.models.cnn import ZOO_SPECS, cnn_forward
from repro.serving.mux_server import MuxServer, MuxServerConfig


@pytest.fixture(scope="module")
def pipeline():
    cfg = dataclasses.replace(smoke_config(), zoo=("zoo_xs", "zoo_s"),
                              zoo_steps=60, mux_steps=60, batch_size=64,
                              train_samples=1024, eval_samples=512)
    key = jax.random.key(0)
    kt, kd, kz, km, ke = jax.random.split(key, 5)
    templates = make_templates(kt, num_classes=cfg.num_classes,
                               image_size=cfg.image_size)
    train_b = image_dataset(kd, templates, num_samples=cfg.train_samples,
                            batch=cfg.batch_size)
    eval_b = image_dataset(ke, templates, num_samples=cfg.eval_samples,
                           batch=cfg.batch_size)
    zoo_state = mux_train.train_zoo(kz, cfg, train_b)
    mux_params = mux_train.train_mux(km, cfg, zoo_state, train_b)
    return cfg, zoo_state, mux_params, eval_b


@pytest.mark.slow
def test_mux_weights_meaningful(pipeline):
    cfg, zoo_state, mux_params, eval_b = pipeline
    names = list(cfg.zoo)
    costs = cfg.costs()
    carr = jnp.asarray([costs[n] for n in names])
    accs = {n: [] for n in names}
    singles, flops = [], []
    for b in eval_b:
        probs, embeds, logits = mux_train.zoo_apply(zoo_state, b["image"], names)
        w, _ = mux_forward(mux_params, b["image"])
        m = ens.policy_metrics(w, probs, b["label"], carr)
        singles.append(float(m["acc_single"]))
        flops.append(float(m["flops_single"]))
        for i, n in enumerate(names):
            accs[n].append(float(jnp.mean(jnp.argmax(probs[i], -1) == b["label"])))
    best_single = max(np.mean(accs[n]) for n in names)
    acc = np.mean(singles)
    # routed accuracy within small tolerance of (usually above) best model
    assert acc >= best_single - 0.05, (acc, best_single)
    # cost-aware routing never exceeds the always-largest budget; the
    # >1x saving factor itself is validated at benchmark scale (Table II)
    assert np.mean(flops) <= max(carr.tolist()) + 1e-6


@pytest.mark.slow
def test_contrastive_separation(pipeline):
    cfg, zoo_state, mux_params, eval_b = pipeline
    names = list(cfg.zoo)
    b = eval_b[0]
    probs, embeds, logits = mux_train.zoo_apply(zoo_state, b["image"], names)
    projected = cnt.project(zoo_state["proj"], embeds)
    correct = {n: jnp.argmax(logits[n], -1) == b["label"] for n in names}
    s = cnt.separation_score(projected, correct)
    assert float(s["push_mean"]) > float(s["pull_mean"]), s


@pytest.mark.slow
def test_mux_server_end_to_end(pipeline):
    cfg, zoo_state, mux_params, eval_b = pipeline
    names = list(cfg.zoo)
    costs = cfg.costs()

    def make_fn(n):
        return lambda xs: cnn_forward(
            zoo_state["zoo"][n], xs,
            convs_per_stage=ZOO_SPECS[n].get("convs_per_stage", 1))[0]

    server = MuxServer(mux_params, [make_fn(n) for n in names],
                       [costs[n] for n in names],
                       MuxServerConfig(capacity_factor=2.0))
    batch = eval_b[0]
    res = server.serve(batch["image"])
    assert res["output"].shape == (batch["image"].shape[0], cfg.num_classes)
    assert abs(sum(res["called_fraction"]) - 1.0) < 1e-6
    assert res["mean_flops"] <= max(costs.values())
    # served predictions match running the assigned model directly
    kept = np.asarray(res["kept"])
    assign = np.asarray(res["assign"])
    out = np.asarray(res["output"])
    for i in np.where(kept)[0][:8]:
        direct = make_fn(names[assign[i]])(batch["image"][i:i + 1])
        np.testing.assert_allclose(out[i], np.asarray(direct[0]), atol=1e-4)
