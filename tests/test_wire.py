"""Wire robustness: version negotiation, frame hygiene, auth.

The cluster transport's failure modes are typed and tested here,
separate from the happy-path cluster tests:

* version mismatch is rejected in BOTH directions (a legacy v1 hello
  against this server, and this client against a v1 server), with
  ``WireVersionError`` naming the versions each side speaks;
* truncated and garbage frames raise promptly instead of desyncing
  the stream;
* a client with the wrong shared secret is refused before it can
  issue a single op;
* the wire schema round-trips arbitrary JSON-shaped payloads
  (hypothesis fuzz, skipped when hypothesis is not installed).
"""
import asyncio

import numpy as np
import pytest

from repro.serving.backend import (WIRE_VERSION, WIRE_VERSIONS, BackendServer,
                                   WireVersionError, negotiate_wire_version,
                                   wire_decode, wire_encode,
                                   wire_error_payload, wire_error_rehydrate)
from repro.serving.cluster import (MAX_FRAME_BYTES, FrameError,
                                   SocketBackendServer, SocketClientBackend,
                                   encode_frame, read_frame)
from repro.serving.cluster.transport import _mac
from repro.serving.cluster.serve import build_tiny_backend


# ---------------------------------------------------------------------------
# Version negotiation, both directions
# ---------------------------------------------------------------------------

def test_negotiate_picks_newest_common():
    assert negotiate_wire_version(list(WIRE_VERSIONS)) == WIRE_VERSION
    assert negotiate_wire_version([*WIRE_VERSIONS, 99]) == WIRE_VERSION
    with pytest.raises(WireVersionError, match="this build speaks"):
        negotiate_wire_version([1])          # legacy v1 has no overlap
    with pytest.raises(WireVersionError):
        negotiate_wire_version([])


def test_v1_client_hello_rejected_by_server():
    """A legacy v1 hello (no versions list — its envelope 'v' is the
    whole claim) gets a typed rejection from this server."""
    srv = BackendServer(build_tiny_backend())

    async def main():
        with pytest.raises(WireVersionError):
            await srv._dispatch({"v": 1, "id": 0, "op": "hello", "body": {}})

    asyncio.run(main())


def test_v2_client_rejects_v1_server():
    """This client against a fake v1 server: the handshake completes,
    the hello reply claims v=1, and the client refuses with
    WireVersionError instead of limping along mis-framed."""

    async def main():
        secret = "repro-cluster"

        async def fake_v1(reader, writer):
            nonce = "00" * 16
            writer.write(encode_frame({"op": "challenge", "nonce": nonce}))
            await writer.drain()
            auth = await read_frame(reader)
            assert auth["mac"] == _mac(secret, nonce, auth["client_id"])
            writer.write(encode_frame({"op": "auth_ok", "host": "old"}))
            await writer.drain()
            hello = await read_frame(reader)
            writer.write(encode_frame({"v": 1, "id": hello["id"],
                                       "ok": {"v": 1, "page_size": 4,
                                              "num_pages": 8,
                                              "decode_batch": 1,
                                              "max_len": 32}}))
            await writer.drain()
            await reader.read()           # EOF: the client hung up
            writer.close()
            await writer.wait_closed()

        server = await asyncio.start_server(fake_v1, "127.0.0.1", 0)
        port = server.sockets[0].getsockname()[1]
        cli = SocketClientBackend("127.0.0.1", port, secret=secret,
                                  timeout_s=0.5)
        with pytest.raises(WireVersionError, match="this client speaks"):
            await cli.start()
        await cli.stop()
        server.close()
        await server.wait_closed()

    asyncio.run(main())


def test_wire_error_roundtrips_victim_tags():
    """Both request-local victim tags (cow_seq AND grow_seq) survive
    the wire: serialized to sids against the server's table, resolved
    back to mirrors on the client — the attribution the scheduler
    needs to fail one request instead of the backend."""
    from repro.serving.kv_cache import OutOfPages

    server_seq, client_mirror = object(), object()
    for tag in ("cow_seq", "grow_seq"):
        exc = OutOfPages("page pool exhausted")
        setattr(exc, tag, server_seq)
        err = wire_error_payload(exc, {7: server_seq})
        assert err["type"] == "OutOfPages"
        assert err[tag.replace("_seq", "_sid")] == 7
        back = wire_error_rehydrate(err, {7: client_mirror})
        assert isinstance(back, OutOfPages)
        assert getattr(back, tag) is client_mirror
    # an untagged error stays untagged, and unknown sids resolve to
    # nothing rather than a wrong sequence
    err = wire_error_payload(ValueError("nope"), {})
    assert "cow_sid" not in err and "grow_sid" not in err
    back = wire_error_rehydrate({"type": "OutOfPages", "msg": "x",
                                 "cow_sid": 99}, {7: client_mirror})
    assert getattr(back, "cow_seq", None) is None


# ---------------------------------------------------------------------------
# Frame hygiene
# ---------------------------------------------------------------------------

def _reader_with(data: bytes) -> asyncio.StreamReader:
    r = asyncio.StreamReader()
    r.feed_data(data)
    r.feed_eof()
    return r


def test_truncated_frame_raises_incomplete():
    async def main():
        # torn length prefix
        with pytest.raises(asyncio.IncompleteReadError):
            await read_frame(_reader_with(b"\x00\x00"))
        # full prefix, torn payload
        good = encode_frame({"op": "ping"})
        with pytest.raises(asyncio.IncompleteReadError):
            await read_frame(_reader_with(good[:-2]))

    asyncio.run(main())


def test_garbage_frames_raise_frame_error():
    async def main():
        # length prefix past the cap (a desynced or hostile stream)
        huge = (MAX_FRAME_BYTES + 1).to_bytes(4, "big")
        with pytest.raises(FrameError, match="not a frame boundary"):
            await read_frame(_reader_with(huge))
        # valid prefix, non-JSON payload
        junk = len(b"\xff\xfe!").to_bytes(4, "big") + b"\xff\xfe!"
        with pytest.raises(FrameError):
            await read_frame(_reader_with(junk))
        # valid JSON that is not an object
        arr = b"[1, 2]"
        with pytest.raises(FrameError, match="expected an object"):
            await read_frame(_reader_with(len(arr).to_bytes(4, "big") + arr))

    asyncio.run(main())


def test_encode_frame_rejects_oversized():
    with pytest.raises(FrameError, match="exceeds MAX_FRAME_BYTES"):
        encode_frame({"blob": "x" * (MAX_FRAME_BYTES + 1)})


def test_frame_round_trip():
    async def main():
        msg = {"op": "decode", "id": 7,
               "body": {"sids": np.asarray([1, 2]), "t": np.float32(0.5)}}
        out = await read_frame(_reader_with(encode_frame(msg)))
        assert out == {"op": "decode", "id": 7,
                       "body": {"sids": [1, 2], "t": 0.5}}

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Auth
# ---------------------------------------------------------------------------

def test_wrong_secret_refused_before_any_op():
    async def main():
        srv = SocketBackendServer(build_tiny_backend(), secret="right",
                                  host_label="h0")
        await srv.start()
        cli = SocketClientBackend("127.0.0.1", srv.port, secret="wrong",
                                  timeout_s=0.5)
        with pytest.raises(PermissionError, match="auth rejected"):
            await cli.start()
        await cli.stop()
        assert srv.auth_failures == 1
        # the right secret still works on the same listener
        ok = SocketClientBackend("127.0.0.1", srv.port, secret="right",
                                 timeout_s=0.5)
        await ok.start()
        assert ok.connected
        await ok.stop()
        await srv.close()

    asyncio.run(main())


# ---------------------------------------------------------------------------
# Schema fuzz (optional dependency)
# ---------------------------------------------------------------------------

def test_wire_schema_fuzz_round_trip():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    json_values = st.recursive(
        st.none() | st.booleans()
        | st.integers(min_value=-2**53, max_value=2**53)
        | st.floats(allow_nan=False, allow_infinity=False)
        | st.text(max_size=20),
        lambda inner: st.lists(inner, max_size=4)
        | st.dictionaries(st.text(max_size=8), inner, max_size=4),
        max_leaves=20)
    msgs = st.dictionaries(st.text(min_size=1, max_size=8), json_values,
                           max_size=6)

    @hypothesis.given(msgs)
    @hypothesis.settings(max_examples=50, deadline=None)
    def round_trips(msg):
        assert wire_decode(wire_encode(msg)) == msg

    round_trips()
